"""Recurrent sequence mixers: Mamba2 (SSD) and xLSTM (mLSTM + sLSTM).

All three share the matrix-state recurrence

    S_t = a_t * S_{t-1} + i_t * k_t v_t^T          (per head)
    y_t = q_t . S_t          (+ optional normalizer n_t = a n + i k)

computed two ways:

* ``chunked_gla`` — chunk-parallel form used for train/prefill: intra-chunk
  attention-like matmul (MXU-friendly) + inter-chunk state carry.  This is
  the TPU adaptation of the SSD algorithm: the quadratic intra-chunk block
  maps to the MXU; the O(T/chunk) sequential part is a tiny lax.scan.
* ``step_gla`` — exact single-token recurrence for decode, and the oracle
  the chunked form is tested against.

mLSTM uses exponential input gates and therefore carries a running
log-stabilizer ``m`` (states are stored as S * exp(-m)).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.common import loops

from repro.configs.base import SSMConfig
from repro.models.layers import dense_param, _dense_init, init_rmsnorm, rmsnorm

# ---------------------------------------------------------------------------
# gated linear attention core
# ---------------------------------------------------------------------------


def gla_init_state(B, H, dk, dv, normalize: bool):
    s = {
        "S": jnp.zeros((B, H, dk, dv), jnp.float32),
    }
    if normalize:
        s["n"] = jnp.zeros((B, H, dk), jnp.float32)
        s["m"] = jnp.zeros((B, H), jnp.float32)
    return s


def step_gla(q, k, v, g, gi, state, *, normalize: bool, eps=1e-6):
    """One recurrence step.

    q,k: (B,H,dk); v: (B,H,dv); g: (B,H) log-decay; gi: (B,H) log-input-gate
    (None -> 0).  Returns y (B,H,dv), new state.
    """
    S = state["S"]
    gi = jnp.zeros_like(g) if gi is None else gi
    if normalize:
        n, m = state["n"], state["m"]
        m_new = jnp.maximum(g + m, gi)
        a = jnp.exp(g + m - m_new)[..., None, None]
        b = jnp.exp(gi - m_new)[..., None, None]
        S = a * S + b * (k[..., :, None] * v[..., None, :])
        n = a[..., 0] * n + b[..., 0] * k
        num = jnp.einsum("bhk,bhkv->bhv", q, S)
        den = jnp.einsum("bhk,bhk->bh", q, n)
        den = jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[..., None]
        y = num / (den + eps)
        return y, {"S": S, "n": n, "m": m_new}
    a = jnp.exp(g)[..., None, None]
    b = jnp.exp(gi)[..., None, None]
    S = a * S + b * (k[..., :, None] * v[..., None, :])
    y = jnp.einsum("bhk,bhkv->bhv", q, S)
    return y, {"S": S}


def sequential_gla(q, k, v, g, gi=None, state=None, *, normalize=False, eps=1e-6):
    """Exact step-by-step scan over time — the oracle + verify path.

    q,k: (B,T,H,dk); v: (B,T,H,dv); g/gi: (B,T,H).
    Returns y (B,T,H,dv), final state, and (optionally) all intermediate
    states stacked on a leading T axis when ``return_states=True`` via
    ``sequential_gla_states``.
    """
    B, T, H, dk = q.shape
    dv = v.shape[-1]
    state = state or gla_init_state(B, H, dk, dv, normalize)

    def body(st, xs):
        qt, kt, vt, gt, git = xs
        y, st = step_gla(qt, kt, vt, gt, git, st, normalize=normalize, eps=eps)
        return st, y

    gi_seq = jnp.zeros_like(g) if gi is None else gi
    xs = (
        jnp.moveaxis(q, 1, 0),
        jnp.moveaxis(k, 1, 0),
        jnp.moveaxis(v, 1, 0),
        jnp.moveaxis(g, 1, 0),
        jnp.moveaxis(gi_seq, 1, 0),
    )
    state, ys = loops.scan(body, state, xs)
    return jnp.moveaxis(ys, 0, 1), state


def sequential_gla_states(q, k, v, g, gi=None, state=None, *, normalize=False, eps=1e-6):
    """Like sequential_gla but also stacks the state after every step
    (leading axis T) — used by speculative verify for rollback."""
    B, T, H, dk = q.shape
    dv = v.shape[-1]
    state = state or gla_init_state(B, H, dk, dv, normalize)

    def body(st, xs):
        qt, kt, vt, gt, git = xs
        y, st = step_gla(qt, kt, vt, gt, git, st, normalize=normalize, eps=eps)
        return st, (y, st)

    gi_seq = jnp.zeros_like(g) if gi is None else gi
    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (q, k, v, g, gi_seq))
    _, (ys, states) = loops.scan(body, state, xs)
    return jnp.moveaxis(ys, 0, 1), states  # states leaves: (T, B, ...)


def chunked_gla(
    q, k, v, g, gi=None, state=None, *, normalize=False, chunk=256, eps=1e-6
):
    """Chunk-parallel gated linear attention (SSD-style).

    Equivalent to ``sequential_gla`` (up to fp error); quadratic only within
    ``chunk``-sized blocks.
    """
    B, T, H, dk = q.shape
    dv = v.shape[-1]
    state = state or gla_init_state(B, H, dk, dv, normalize)
    Lc = min(chunk, T)
    pad = (-T) % Lc
    if pad:
        z4 = lambda x: jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        z3 = lambda x: jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        q, k, v = z4(q), z4(k), z4(v)
        g = z3(g)  # pad with 0 = no decay
        if gi is not None:
            # padded positions must contribute no input: log-gate -> -inf
            gi = jnp.pad(
                gi, ((0, 0), (0, pad), (0, 0)), constant_values=-1e30
            )
    NC = (T + pad) // Lc

    def split(x):
        return jnp.moveaxis(x.reshape(B, NC, Lc, *x.shape[2:]), 1, 0)

    qs, ks, vs, gs = split(q), split(k), split(v), split(g)
    gis = split(gi) if gi is not None else jnp.zeros_like(gs)

    tri = jnp.tril(jnp.ones((Lc, Lc), bool))            # j <= i
    tri_strict = jnp.tril(jnp.ones((Lc, Lc), bool), -1)

    def body(st, xs):
        qc, kc, vc, gc, gic = xs                        # (B, Lc, H, ·)
        qc32 = qc.astype(jnp.float32)
        kc32 = kc.astype(jnp.float32)
        vc32 = vc.astype(jnp.float32)
        G = jnp.cumsum(gc, axis=1)                      # (B, Lc, H)
        GL = G[:, -1]                                   # (B, H)
        # intra log-weights  s_ij = G_i - G_j + gi_j   (j <= i)
        s = G[:, :, None, :] - G[:, None, :, :] + gic[:, None, :, :]
        s = jnp.where(tri[None, :, :, None], s, -jnp.inf)
        # state-update log-weights  u_j = GL - G_j + gi_j
        u = GL[:, None, :] - G + gic                    # (B, Lc, H)
        qk = jnp.einsum("bihk,bjhk->bijh", qc32, kc32)  # (B, Lc, Lc, H)

        if normalize:
            m_prev = st["m"]                            # (B, H)
            row_max = jnp.max(s, axis=2)                # (B, Lc, H)
            m_i = jnp.maximum(row_max, G + m_prev[:, None, :])
            A = jnp.exp(s - m_i[:, :, None, :])         # masked rows -> 0
            A = jnp.where(tri[None, :, :, None], A, 0.0)
            inter_w = jnp.exp(G + m_prev[:, None, :] - m_i)  # (B, Lc, H)
            num = jnp.einsum("bijh,bjhv->bihv", A * qk, vc32)
            num += inter_w[..., None] * jnp.einsum("bihk,bhkv->bihv", qc32, st["S"])
            den = jnp.einsum("bijh,bijh->bih", A, qk)
            den += inter_w * jnp.einsum("bihk,bhk->bih", qc32, st["n"])
            den = jnp.maximum(jnp.abs(den), jnp.exp(-m_i))
            y = num / (den[..., None] + eps)
            # state update
            m_new = jnp.maximum(GL + m_prev, jnp.max(u, axis=1))  # (B, H)
            w_u = jnp.exp(u - m_new[:, None, :])        # (B, Lc, H)
            carry = jnp.exp(GL + m_prev - m_new)
            S = carry[..., None, None] * st["S"] + jnp.einsum(
                "bjh,bjhk,bjhv->bhkv", w_u, kc32, vc32
            )
            n = carry[..., None] * st["n"] + jnp.einsum("bjh,bjhk->bhk", w_u, kc32)
            return {"S": S, "n": n, "m": m_new}, y

        A = jnp.where(tri[None, :, :, None], jnp.exp(s), 0.0)
        y = jnp.einsum("bijh,bjhv->bihv", A * qk, vc32)
        y += jnp.exp(G)[..., None] * jnp.einsum("bihk,bhkv->bihv", qc32, st["S"])
        w_u = jnp.exp(u)
        S = jnp.exp(GL)[..., None, None] * st["S"] + jnp.einsum(
            "bjh,bjhk,bjhv->bhkv", w_u, kc32, vc32
        )
        return {"S": S}, y

    state, ys = loops.scan(body, state, (qs, ks, vs, gs, gis))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, T + pad, H, dv)[:, :T]
    return y.astype(v.dtype), state


# ---------------------------------------------------------------------------
# causal depthwise conv (Mamba / xLSTM frontends)
# ---------------------------------------------------------------------------


def causal_conv1d(x, w, conv_state=None):
    """Depthwise causal conv.  x: (B, T, C); w: (K, C).

    With ``conv_state`` (B, K-1, C) uses it as left context and returns the
    new state (last K-1 inputs).
    """
    B, T, C = x.shape
    K = w.shape[0]
    if conv_state is None:
        ctxt = jnp.zeros((B, K - 1, C), x.dtype)
    else:
        ctxt = conv_state.astype(x.dtype)
    xp = jnp.concatenate([ctxt, x], axis=1)            # (B, T+K-1, C)
    out = jnp.zeros((B, T, C), jnp.float32)
    for i in range(K):  # K is tiny (4): unrolled taps, no gather
        out = out + xp[:, i : i + T].astype(jnp.float32) * w[i].astype(jnp.float32)
    new_state = xp[:, T:]                               # (B, K-1, C)
    return out.astype(x.dtype), new_state


# ---------------------------------------------------------------------------
# Mamba2 block
# ---------------------------------------------------------------------------


def mamba2_dims(d_model, ssm: SSMConfig, n_heads):
    d_inner = ssm.expand * d_model
    head_p = d_inner // n_heads
    return d_inner, head_p


def init_mamba2(rng, d_model, ssm: SSMConfig, n_heads, dtype):
    d_inner, head_p = mamba2_dims(d_model, ssm, n_heads)
    N = ssm.state_dim
    conv_dim = d_inner + 2 * N
    ks = jax.random.split(rng, 5)
    return {
        "norm": init_rmsnorm(d_model, dtype),
        "in_proj": dense_param(
            ks[0], d_model, (2 * d_inner + 2 * N + n_heads,), dtype
        ),
        "conv_w": (jax.random.normal(ks[1], (ssm.conv_kernel, conv_dim)) * 0.1).astype(dtype),
        "A_log": jnp.zeros((n_heads,), jnp.float32),
        "D": jnp.ones((n_heads,), jnp.float32),
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "gnorm": init_rmsnorm(d_inner, dtype),
        "out_proj": dense_param(ks[2], d_inner, (d_model,), dtype),
    }


def mamba2_axes():
    return {
        "norm": ("embed",),
        "in_proj": ("embed", "mlp"),
        "conv_w": ("conv", "mlp"),
        "A_log": (None,),
        "D": (None,),
        "dt_bias": (None,),
        "gnorm": ("mlp",),
        "out_proj": ("mlp", "embed"),
    }


def _mamba2_pre(p, x, d_model, ssm: SSMConfig, n_heads, conv_state):
    """Shared projection+conv path.  Returns q,k,v,g,(z),new conv state."""
    B, T, _ = x.shape
    d_inner, head_p = mamba2_dims(d_model, ssm, n_heads)
    N = ssm.state_dim
    h = rmsnorm(x, p["norm"])
    proj = jnp.einsum("btd,de->bte", h, p["in_proj"])
    z, xBC, dt_raw = jnp.split(proj, [d_inner, 2 * d_inner + 2 * N], axis=-1)
    xBC, new_conv = causal_conv1d(xBC, p["conv_w"], conv_state)
    xBC = jax.nn.silu(xBC)
    xin, Bmat, Cmat = jnp.split(xBC, [d_inner, d_inner + N], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,T,H)
    A = -jnp.exp(p["A_log"])                                         # (H,)
    g = dt * A                                                        # log-decay
    xh = xin.reshape(B, T, n_heads, head_p)
    v = xh * dt[..., None]                     # fold dt into the input term
    k = jnp.broadcast_to(Bmat[:, :, None, :], (B, T, n_heads, N))
    q = jnp.broadcast_to(Cmat[:, :, None, :], (B, T, n_heads, N))
    return q, k, v, g, z, xh, new_conv


def _mamba2_post(p, y, xh, z, d_model, n_heads):
    B, T = y.shape[:2]
    y = y + p["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(B, T, -1).astype(z.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["gnorm"])
    return jnp.einsum("bte,ed->btd", y, p["out_proj"])


def mamba2_forward(p, x, d_model, ssm: SSMConfig, n_heads, state=None, *, chunked=True):
    """x: (B,T,D) -> (y, new_state).  state = {'conv': .., 'ssm': gla state}."""
    conv_state = state["conv"] if state else None
    gla_state = state["ssm"] if state else None
    q, k, v, g, z, xh, new_conv = _mamba2_pre(p, x, d_model, ssm, n_heads, conv_state)
    if chunked:
        y, new_gla = chunked_gla(q, k, v, g, state=gla_state, chunk=ssm.chunk)
    else:
        y, new_gla = sequential_gla(q, k, v, g, state=gla_state)
    out = _mamba2_post(p, y.astype(jnp.float32), xh, z, d_model, n_heads)
    return x + out, {"conv": new_conv, "ssm": new_gla}


def mamba2_init_state(B, d_model, ssm: SSMConfig, n_heads):
    d_inner, head_p = mamba2_dims(d_model, ssm, n_heads)
    N = ssm.state_dim
    return {
        "conv": jnp.zeros((B, ssm.conv_kernel - 1, d_inner + 2 * N), jnp.bfloat16),
        "ssm": gla_init_state(B, n_heads, N, head_p, normalize=False),
    }


# ---------------------------------------------------------------------------
# mLSTM block (xLSTM)
# ---------------------------------------------------------------------------


def mlstm_dims(d_model):
    return 2 * d_model  # pf = 2


def init_mlstm(rng, d_model, n_heads, dtype, conv_kernel=4):
    di = mlstm_dims(d_model)
    ks = jax.random.split(rng, 8)
    return {
        "norm": init_rmsnorm(d_model, dtype),
        "up": dense_param(ks[0], d_model, (2 * di,), dtype),   # [u, z]
        "conv_w": (jax.random.normal(ks[1], (conv_kernel, di)) * 0.1).astype(dtype),
        "wq": dense_param(ks[2], di, (di,), dtype),
        "wk": dense_param(ks[3], di, (di,), dtype),
        "wv": dense_param(ks[4], di, (di,), dtype),
        "w_if": dense_param(ks[5], di, (2 * n_heads,), jnp.float32),
        "b_if": jnp.concatenate(
            [jnp.zeros((n_heads,)), 3.0 * jnp.ones((n_heads,))]
        ).astype(jnp.float32),
        "gnorm": init_rmsnorm(di, dtype),
        "skip": jnp.ones((di,), dtype),
        "down": dense_param(ks[6], di, (d_model,), dtype),
    }


def mlstm_axes():
    return {
        "norm": ("embed",),
        "up": ("embed", "mlp"),
        "conv_w": ("conv", "mlp"),
        "wq": ("mlp", "heads"),
        "wk": ("mlp", "heads"),
        "wv": ("mlp", "heads"),
        "w_if": ("mlp", None),
        "b_if": (None,),
        "gnorm": ("mlp",),
        "skip": ("mlp",),
        "down": ("mlp", "embed"),
    }


def _mlstm_pre(p, x, n_heads, conv_state):
    B, T, D = x.shape
    di = mlstm_dims(D)
    hd = di // n_heads
    h = rmsnorm(x, p["norm"])
    u, z = jnp.split(jnp.einsum("btd,de->bte", h, p["up"]), 2, axis=-1)
    c, new_conv = causal_conv1d(u, p["conv_w"], conv_state)
    c = jax.nn.silu(c)
    q = jnp.einsum("bte,ef->btf", c, p["wq"]).reshape(B, T, n_heads, hd)
    k = jnp.einsum("bte,ef->btf", c, p["wk"]).reshape(B, T, n_heads, hd)
    k = k * hd**-0.5
    v = jnp.einsum("bte,ef->btf", u, p["wv"]).reshape(B, T, n_heads, hd)
    gates = jnp.einsum("bte,eg->btg", c.astype(jnp.float32), p["w_if"]) + p["b_if"]
    i_raw, f_raw = jnp.split(gates, 2, axis=-1)           # (B,T,H)
    log_f = -jax.nn.softplus(-f_raw)                       # log sigmoid(f)
    log_i = i_raw                                          # exponential gate
    return q, k, v, log_f, log_i, z, c, new_conv


def _mlstm_post(p, y, c, z, n_heads):
    B, T = y.shape[:2]
    y = y.reshape(B, T, -1).astype(z.dtype)
    y = rmsnorm(y, p["gnorm"]) + p["skip"] * c
    y = y * jax.nn.silu(z)
    return jnp.einsum("bte,ed->btd", y, p["down"])


def mlstm_forward(p, x, n_heads, state=None, *, chunk=256, chunked=True):
    conv_state = state["conv"] if state else None
    gla_state = state["gla"] if state else None
    q, k, v, log_f, log_i, z, c, new_conv = _mlstm_pre(p, x, n_heads, conv_state)
    fn = chunked_gla if chunked else sequential_gla
    kw = {"chunk": chunk} if chunked else {}
    y, new_gla = fn(q, k, v, log_f, log_i, state=gla_state, normalize=True, **kw)
    out = _mlstm_post(p, y.astype(jnp.float32), c, z, n_heads)
    return x + out, {"conv": new_conv, "gla": new_gla}


def mlstm_init_state(B, d_model, n_heads, conv_kernel=4):
    di = mlstm_dims(d_model)
    hd = di // n_heads
    return {
        "conv": jnp.zeros((B, conv_kernel - 1, di), jnp.bfloat16),
        "gla": gla_init_state(B, n_heads, hd, hd, normalize=True),
    }


# ---------------------------------------------------------------------------
# sLSTM block (xLSTM) — strictly sequential scalar-memory recurrence
# ---------------------------------------------------------------------------


def init_slstm(rng, d_model, n_heads, dtype):
    hd = d_model // n_heads
    ks = jax.random.split(rng, 6)
    f_mlp = int(d_model * 4 / 3)
    return {
        "norm": init_rmsnorm(d_model, dtype),
        "w_gates": dense_param(ks[0], d_model, (4 * d_model,), dtype),
        # block-diagonal recurrent weights: (4, H, hd, hd)
        "r_gates": (jax.random.normal(ks[1], (4, n_heads, hd, hd)) * hd**-0.5).astype(dtype),
        "b_gates": jnp.concatenate(
            [
                jnp.zeros((2 * d_model,)),
                jnp.ones((d_model,)),     # forget bias
                jnp.zeros((d_model,)),
            ]
        ).astype(jnp.float32),
        "gnorm": init_rmsnorm(d_model, dtype),
        "mlp_up": dense_param(ks[2], d_model, (2 * f_mlp,), dtype),
        "mlp_down": dense_param(ks[3], f_mlp, (d_model,), dtype),
    }


def slstm_axes():
    return {
        "norm": ("embed",),
        "w_gates": ("embed", "mlp"),
        "r_gates": (None, "heads", "head_dim", None),
        "b_gates": (None,),
        "gnorm": ("embed",),
        "mlp_up": ("embed", "mlp"),
        "mlp_down": ("mlp", "embed"),
    }


def slstm_init_state(B, d_model):
    z = jnp.zeros((B, d_model), jnp.float32)
    return {"h": z, "c": z, "n": z, "m": z}


def slstm_forward(p, x, n_heads, state=None):
    """Sequential sLSTM.  x: (B,T,D)."""
    B, T, D = x.shape
    hd = D // n_heads
    state = state or slstm_init_state(B, D)
    xin = rmsnorm(x, p["norm"])
    wx = jnp.einsum("btd,de->bte", xin, p["w_gates"]).astype(jnp.float32)

    def step(st, wx_t):
        h, c, n, m = st["h"], st["c"], st["n"], st["m"]
        hh = h.reshape(B, n_heads, hd)
        rec = jnp.einsum(
            "bhk,ghkl->bghl", hh.astype(p["r_gates"].dtype), p["r_gates"]
        ).astype(jnp.float32).reshape(B, 4 * D)
        pre = wx_t + rec + p["b_gates"]
        zt, it, ft, ot = jnp.split(pre, 4, axis=-1)
        zt = jnp.tanh(zt)
        ot = jax.nn.sigmoid(ot)
        log_f = -jax.nn.softplus(-ft)
        m_new = jnp.maximum(log_f + m, it)
        ip = jnp.exp(it - m_new)
        fp = jnp.exp(log_f + m - m_new)
        c_new = fp * c + ip * zt
        n_new = fp * n + ip
        h_new = ot * c_new / jnp.maximum(n_new, 1e-6)
        return {"h": h_new, "c": c_new, "n": n_new, "m": m_new}, h_new

    state, hs = loops.scan(step, state, jnp.moveaxis(wx, 1, 0))
    hs = jnp.moveaxis(hs, 0, 1).astype(x.dtype)         # (B,T,D)
    y = rmsnorm(hs, p["gnorm"])
    u, g = jnp.split(jnp.einsum("btd,df->btf", y, p["mlp_up"]), 2, axis=-1)
    y = jnp.einsum("btf,fd->btd", jax.nn.gelu(u) * jax.nn.sigmoid(g), p["mlp_down"])
    return x + y, state
