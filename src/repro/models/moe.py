"""Mixture-of-Experts layer: top-k routing with GShard-style group-wise
capacity-bounded dispatch (TPU-idiomatic: one batched matmul per expert
weight, no per-token gather loops, no cross-shard sequential scans).

Dispatch (per group = per sequence, so ranking parallelizes over the
data-sharded batch axis):
  1. router logits -> top-k (expert_id, weight) per token;
  2. rank of each (token, k) assignment within its expert via a cumulative
     count over the group's token axis;
  3. scatter token activations into a dense (B, E, C, D) buffer (assignments
     whose rank exceeds capacity C are dropped — their weight is zeroed so
     the residual path carries those tokens, standard capacity semantics);
  4. batched expert FFN on the buffer;
  5. gather back + combine with routing weights.

Parallelism:
  * "tp": expert FFN hidden dim sharded on `model` (dense-MLP-like comms);
  * "ep": expert dim sharded on `model` — GSPMD materializes the token
    all-to-all when resharding the dispatch buffer batch->expert.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.models.layers import dense_param, _dense_init


def init_moe(rng, d_model, d_ff, cfg: MoEConfig, dtype):
    fe = cfg.d_expert or d_ff
    ks = jax.random.split(rng, 7)
    p = {
        "router": dense_param(ks[0], d_model, (cfg.num_experts,), jnp.float32),
        "w_gate": _dense_init(
            ks[1], (cfg.num_experts, d_model, fe), d_model, dtype
        ),
        "w_up": _dense_init(ks[2], (cfg.num_experts, d_model, fe), d_model, dtype),
        "w_down": _dense_init(ks[3], (cfg.num_experts, fe, d_model), fe, dtype),
    }
    if cfg.num_shared_experts:
        fs = fe * cfg.num_shared_experts
        p["shared"] = {
            "gate": dense_param(ks[4], d_model, (fs,), dtype),
            "up": dense_param(ks[5], d_model, (fs,), dtype),
            "down": _dense_init(ks[6], (fs, d_model), fs, dtype),
        }
    return p


def moe_axes(cfg: MoEConfig):
    if cfg.parallelism == "ep":
        w = ("expert", "embed", None)
        wd = ("expert", None, "embed")
    else:  # tp: shard the expert hidden dim like a dense MLP
        w = (None, "embed", "mlp")
        wd = (None, "mlp", "embed")
    a = {
        "router": ("embed", None),
        "w_gate": w,
        "w_up": w,
        "w_down": wd,
    }
    if cfg.num_shared_experts:
        a["shared"] = {
            "gate": ("embed", "mlp"),
            "up": ("embed", "mlp"),
            "down": ("mlp", "embed"),
        }
    return a


def expert_capacity(cfg: MoEConfig, tokens_per_group: int) -> int:
    C = int(round(tokens_per_group * cfg.top_k / cfg.num_experts * cfg.capacity_factor))
    return max(cfg.top_k, min(C, tokens_per_group))


def moe_apply(p, x, cfg: MoEConfig, *, ctx=None, rng=None, dropless=False):
    """x: (B, S, D) -> ((B, S, D), aux losses).  Groups = batch rows.

    ``dropless=True`` (decode/verify paths) sets capacity = S so no
    assignment is ever dropped: speculative verification must be a
    deterministic function of the context, independent of how many draft
    tokens share the microbatch.  Training keeps GShard capacity semantics.
    """
    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.top_k

    logits = jnp.einsum(
        "bsd,de->bse", x.astype(jnp.float32), p["router"]
    )  # (B, S, E) f32
    if cfg.router_jitter and rng is not None:
        logits = logits + cfg.router_jitter * jax.random.normal(rng, logits.shape)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, K)                      # (B, S, K)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    C = S if dropless else expert_capacity(cfg, S)

    # rank within (group, expert): cumulative count along the S*K axis
    flat_e = top_e.reshape(B, S * K)                            # (B, S*K)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)         # (B, S*K, E)
    ranks = (jnp.cumsum(onehot, axis=1) - onehot) * onehot
    rank = ranks.sum(-1)                                        # (B, S*K)
    keep = rank < C
    slot = flat_e * C + jnp.minimum(rank, C - 1)                # (B, S*K)
    oob = E * C                                                  # drop sentinel

    # scatter tokens into (B, E*C, D)
    src = jnp.repeat(x, K, axis=1)                              # (B, S*K, D)
    buf = jnp.zeros((B, E * C, D), x.dtype)
    scatter_idx = jnp.where(keep, slot, oob)[..., None]         # (B, S*K, 1)
    buf = jax.vmap(
        lambda b, i, s: b.at[i[..., 0]].add(s, mode="drop")
    )(buf, scatter_idx, src)
    buf = buf.reshape(B, E, C, D)
    if ctx is not None:
        buf = ctx.cs(buf, ("act_batch", "act_expert", None, None))

    # expert FFN (batched over E; groups stay data-sharded)
    g = jnp.einsum("becd,edf->becf", buf, p["w_gate"])
    u = jnp.einsum("becd,edf->becf", buf, p["w_up"])
    h = jax.nn.silu(g) * u
    if ctx is not None and cfg.parallelism == "tp":
        h = ctx.cs(h, ("act_batch", None, None, "mlp"))
    out_buf = jnp.einsum("becf,efd->becd", h, p["w_down"])
    if ctx is not None:
        out_buf = ctx.cs(out_buf, ("act_batch", "act_expert", None, None))
    out_buf = out_buf.reshape(B, E * C, D)

    # gather back, apply routing weights (dropped tokens contribute 0)
    gathered = jnp.take_along_axis(
        out_buf, jnp.minimum(slot, E * C - 1)[..., None], axis=1
    )                                                           # (B, S*K, D)
    w = (top_w.reshape(B, S * K) * keep.astype(jnp.float32)).astype(x.dtype)
    y = (gathered * w[..., None]).reshape(B, S, K, D).sum(axis=2)

    if cfg.num_shared_experts:
        sg = jnp.einsum("bsd,df->bsf", x, p["shared"]["gate"])
        su = jnp.einsum("bsd,df->bsf", x, p["shared"]["up"])
        y = y + jnp.einsum(
            "bsf,fd->bsd", jax.nn.silu(sg) * su, p["shared"]["down"]
        )

    # load-balance aux loss (Switch-style)
    me = probs.reshape(-1, E).mean(axis=0)
    fe_frac = jax.nn.one_hot(
        top_e[..., 0].reshape(-1), E, dtype=jnp.float32
    ).mean(axis=0)
    aux = {"load_balance": E * jnp.sum(me * fe_frac)}
    return y, aux
