"""Model zoo: layer library + architecture families + unified bundle API."""
from repro.models.zoo import ModelBundle, build, input_specs, batch_specs, batch_axes

__all__ = ["ModelBundle", "build", "input_specs", "batch_specs", "batch_axes"]
