"""Unified model API: every architecture exposes the same bundle of
functions, keyed by config family.

    bundle = build(cfg)
    params  = bundle.init(rng)
    logits, aux = bundle.forward_train(params, batch)
    cache   = bundle.init_cache(B, max_len)
    logits, cache = bundle.prefill(params, batch, cache)
    logits, cache = bundle.decode(params, tokens, cache, pos)   # T >= 1

``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins for every
input of the corresponding jitted step (dry-run: no allocation).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.common.sharding import NULL_CTX
from repro.configs.base import ArchConfig
from repro.configs.shapes import ShapeConfig
from repro.models import encdec, recurrent, transformer


@dataclasses.dataclass(frozen=True)
class ModelBundle:
    cfg: ArchConfig
    init: Callable
    forward_train: Callable
    prefill: Callable
    decode: Callable
    init_cache: Callable
    param_axes: Callable
    cache_axes: Callable


def build(cfg: ArchConfig) -> ModelBundle:
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        return ModelBundle(
            cfg=cfg,
            init=partial(transformer.init_params, cfg),
            forward_train=partial(transformer.forward_train, cfg),
            prefill=partial(transformer.prefill, cfg),
            decode=partial(transformer.decode, cfg),
            init_cache=partial(transformer.init_cache, cfg),
            param_axes=partial(transformer.param_axes, cfg),
            cache_axes=partial(transformer.cache_axes, cfg),
        )
    if fam == "ssm":
        return ModelBundle(
            cfg=cfg,
            init=partial(recurrent.xlstm_init, cfg),
            forward_train=partial(recurrent.xlstm_forward_train, cfg),
            prefill=partial(recurrent.xlstm_prefill, cfg),
            decode=partial(recurrent.xlstm_decode, cfg),
            init_cache=partial(recurrent.xlstm_init_cache, cfg),
            param_axes=partial(recurrent.xlstm_axes, cfg),
            cache_axes=partial(recurrent.xlstm_cache_axes, cfg),
        )
    if fam == "hybrid":
        return ModelBundle(
            cfg=cfg,
            init=partial(recurrent.zamba_init, cfg),
            forward_train=partial(recurrent.zamba_forward_train, cfg),
            prefill=partial(recurrent.zamba_prefill, cfg),
            decode=partial(recurrent.zamba_decode, cfg),
            init_cache=partial(recurrent.zamba_init_cache, cfg),
            param_axes=partial(recurrent.zamba_axes, cfg),
            cache_axes=partial(recurrent.zamba_cache_axes, cfg),
        )
    if fam == "audio":
        return ModelBundle(
            cfg=cfg,
            init=partial(encdec.encdec_init, cfg),
            forward_train=partial(encdec.encdec_forward_train, cfg),
            prefill=partial(encdec.encdec_prefill, cfg),
            decode=partial(encdec.encdec_decode, cfg),
            init_cache=partial(encdec.encdec_init_cache, cfg),
            param_axes=partial(encdec.encdec_axes, cfg),
            cache_axes=partial(encdec.encdec_cache_axes, cfg),
        )
    raise ValueError(f"unknown family {fam!r}")


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStructs, dry-run safe)
# ---------------------------------------------------------------------------


def batch_specs(cfg: ArchConfig, B: int, S: int, with_targets: bool):
    """Model input batch for a full-sequence step (train/prefill)."""
    sd = jax.ShapeDtypeStruct
    specs: dict[str, Any] = {"tokens": sd((B, S), jnp.int32)}
    if with_targets:
        specs["targets"] = sd((B, S), jnp.int32)
    if cfg.family == "vlm":
        specs["image_embeds"] = sd((B, cfg.num_image_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.family == "audio":
        specs["frames"] = sd((B, cfg.encoder_frames, cfg.d_model), jnp.bfloat16)
    return specs


def batch_axes(cfg: ArchConfig, with_targets: bool):
    axes: dict[str, tuple] = {"tokens": ("act_batch", "act_seq")}
    if with_targets:
        axes["targets"] = ("act_batch", "act_seq")
    if cfg.family == "vlm":
        axes["image_embeds"] = ("act_batch", None, "act_embed")
    if cfg.family == "audio":
        axes["frames"] = ("act_batch", None, "act_embed")
    return axes


def cache_specs(cfg: ArchConfig, B: int, max_len: int):
    bundle = build(cfg)
    return jax.eval_shape(lambda: bundle.init_cache(B, max_len))


def input_specs(cfg: ArchConfig, shape: ShapeConfig):
    """Inputs of the jitted step for this (arch, shape) cell.

    train   -> {'batch': {...}}                         for train_step
    prefill -> {'batch': {...}, 'cache': ...}           for prefill_step
    decode  -> {'tokens': (B,1), 'cache': ..., 'pos'}   for serve_step
    """
    sd = jax.ShapeDtypeStruct
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        return {"batch": batch_specs(cfg, B, S, with_targets=True)}
    if shape.kind == "prefill":
        return {
            "batch": batch_specs(cfg, B, S, with_targets=False),
            "cache": cache_specs(cfg, B, S),
        }
    if shape.kind == "decode":
        return {
            "tokens": sd((B, 1), jnp.int32),
            "cache": cache_specs(cfg, B, S),
            "pos": sd((), jnp.int32),
        }
    raise ValueError(shape.kind)
