"""Recurrent-family LMs: xLSTM (mLSTM+sLSTM) and Zamba2 (Mamba2 + shared
attention block).

xLSTM (cfg.family == "ssm"): layers grouped as (slstm_every-1) mLSTM blocks
followed by 1 sLSTM block; outer scan over groups, inner scan over the mLSTM
stack.

Zamba2 (cfg.family == "hybrid"): flat scan over Mamba2 layers; every
``ssm.attn_every`` layers a SHARED full-attention block (same params each
application) runs first — its KV cache has one entry per application.

Decode caches are recurrent states (O(1) per token) — this is why these two
archs run the long_500k cell.  Speculative rollback uses state snapshots
(see DESIGN.md §5): `decode` with T>1 uses the exact sequential recurrence.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.common import loops

from repro.common.sharding import NULL_CTX
from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models import ssm as S
from repro.models.transformer import (
    attn_spec,
    _init_block,
    _block_axes,
    _apply_block_full,
    _apply_block_cached,
    _stack_init,
    _stack_axes,
    _logits,
    chunked_ce_loss,
)

# ---------------------------------------------------------------------------
# xLSTM
# ---------------------------------------------------------------------------


def _xlstm_group_sizes(cfg: ArchConfig):
    per = cfg.ssm.slstm_every
    assert cfg.n_layers % per == 0, "n_layers must divide by slstm_every"
    return cfg.n_layers // per, per - 1  # (n_groups, mlstm per group)


def xlstm_init(cfg: ArchConfig, rng, dtype=jnp.bfloat16):
    n_groups, per_m = _xlstm_group_sizes(cfg)
    ke, kl, ku = jax.random.split(rng, 3)

    def group_init(k):
        k1, k2 = jax.random.split(k)
        return {
            "mlstm": _stack_init(
                lambda kk: S.init_mlstm(kk, cfg.d_model, cfg.n_heads, dtype), k1, per_m
            ),
            "slstm": S.init_slstm(k2, cfg.d_model, cfg.n_heads, dtype),
        }

    return {
        "embed": L.init_embedding(ke, cfg.vocab, cfg.d_model, dtype),
        "groups": _stack_init(group_init, kl, n_groups),
        "final_norm": L.init_rmsnorm(cfg.d_model, dtype),
        "unembed": L.dense_param(ku, cfg.d_model, (cfg.vocab,), dtype),
    }


def xlstm_axes(cfg: ArchConfig):
    return {
        "embed": ("vocab", "embed"),
        "groups": {
            "mlstm": _stack_axes(S.mlstm_axes(), ("layers", "layers_inner")),
            "slstm": _stack_axes(S.slstm_axes(), ("layers",)),
        },
        "final_norm": ("embed",),
        "unembed": ("embed", "vocab"),
    }


def xlstm_init_cache(cfg: ArchConfig, B, max_len=0, dtype=jnp.bfloat16):
    n_groups, per_m = _xlstm_group_sizes(cfg)
    m1 = S.mlstm_init_state(B, cfg.d_model, cfg.n_heads)
    stack = lambda tree, n: jax.tree.map(
        lambda x: jnp.broadcast_to(x, (n, *x.shape)), tree
    )
    return {
        "mlstm": stack(stack(m1, per_m), n_groups),
        "slstm": stack(S.slstm_init_state(B, cfg.d_model), n_groups),
    }


def xlstm_cache_axes(cfg: ArchConfig):
    m = {
        "conv": ("layers", "layers_inner", "act_batch", None, "mlp"),
        "gla": {
            "S": ("layers", "layers_inner", "act_batch", "act_heads", None, None),
            "n": ("layers", "layers_inner", "act_batch", "act_heads", None),
            "m": ("layers", "layers_inner", "act_batch", "act_heads"),
        },
    }
    s = {k: ("layers", "act_batch", "act_embed") for k in ("h", "c", "n", "m")}
    return {"mlstm": m, "slstm": s}


def _xlstm_run(cfg, params, x, state, *, chunked, remat=False):
    chunk = cfg.ssm.chunk
    ckpt = (
        (lambda f: jax.checkpoint(f, policy=jax.checkpoint_policies.nothing_saveable))
        if remat
        else (lambda f: f)
    )

    @ckpt
    def group_body(x, inp):
        gp, gstate = inp

        def m_body(xc, inner):
            mp, mstate = inner
            xo, new_state = S.mlstm_forward(
                mp, xc, cfg.n_heads, mstate, chunk=chunk, chunked=chunked
            )
            return xo, new_state

        x, new_m = loops.scan(m_body, x, (gp["mlstm"], gstate["mlstm"]))
        x, new_s = S.slstm_forward(gp["slstm"], x, cfg.n_heads, gstate["slstm"])
        return x, {"mlstm": new_m, "slstm": new_s}

    x, new_state = loops.scan(
        group_body, x, (params["groups"], state)
    )
    return x, new_state


def xlstm_forward_train(cfg, params, batch, *, ctx=NULL_CTX, remat=False):
    tokens = batch["tokens"]
    x = L.embed(params["embed"], tokens)
    x = ctx.cs(x, ("act_batch", "act_seq", "act_embed"))
    state = xlstm_init_cache(cfg, tokens.shape[0])
    x, _ = _xlstm_run(cfg, params, x, state, chunked=True, remat=remat)
    if "targets" in batch:
        loss_sum, n = chunked_ce_loss(cfg, params, x, batch["targets"], ctx=ctx)
        return loss_sum / n.astype(jnp.float32), {}
    return _logits(cfg, params, x), {}


def xlstm_prefill(cfg, params, batch, cache, *, ctx=NULL_CTX,
                  last_only: bool = False):
    tokens = batch["tokens"]
    x = L.embed(params["embed"], tokens)
    x = ctx.cs(x, ("act_batch", "act_seq", "act_embed"))
    x, cache = _xlstm_run(cfg, params, x, cache, chunked=True)
    if last_only:
        x = x[:, -1:]
    return _logits(cfg, params, x), cache


def xlstm_decode(cfg, params, tokens, cache, pos, *, ctx=NULL_CTX):
    x = L.embed(params["embed"], tokens)
    x, cache = _xlstm_run(cfg, params, x, cache, chunked=False)
    return _logits(cfg, params, x), cache


# ---------------------------------------------------------------------------
# Zamba2 hybrid
# ---------------------------------------------------------------------------


def _n_attn_apps(cfg: ArchConfig):
    k = cfg.ssm.attn_every
    return (cfg.n_layers + k - 1) // k


def zamba_init(cfg: ArchConfig, rng, dtype=jnp.bfloat16):
    ke, kl, ka, ku = jax.random.split(rng, 4)
    return {
        "embed": L.init_embedding(ke, cfg.vocab, cfg.d_model, dtype),
        "mamba": _stack_init(
            lambda kk: S.init_mamba2(kk, cfg.d_model, cfg.ssm, cfg.n_heads, dtype),
            kl,
            cfg.n_layers,
        ),
        "shared_attn": _init_block(cfg, ka, dtype),
        "final_norm": L.init_rmsnorm(cfg.d_model, dtype),
        "unembed": L.dense_param(ku, cfg.d_model, (cfg.vocab,), dtype),
    }


def zamba_axes(cfg: ArchConfig):
    return {
        "embed": ("vocab", "embed"),
        "mamba": _stack_axes(S.mamba2_axes()),
        "shared_attn": _block_axes(cfg),
        "final_norm": ("embed",),
        "unembed": ("embed", "vocab"),
    }


def zamba_init_cache(cfg: ArchConfig, B, max_len, dtype=jnp.bfloat16):
    n_apps = _n_attn_apps(cfg)
    hkv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    m1 = S.mamba2_init_state(B, cfg.d_model, cfg.ssm, cfg.n_heads)
    mamba = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (cfg.n_layers, *x.shape)), m1
    )
    return {
        "mamba": mamba,
        "k": jnp.zeros((n_apps, B, max_len, hkv, hd), dtype),
        "v": jnp.zeros((n_apps, B, max_len, hkv, hd), dtype),
    }


def zamba_cache_axes(cfg: ArchConfig):
    return {
        "mamba": {
            "conv": ("layers", "act_batch", None, "mlp"),
            "ssm": {"S": ("layers", "act_batch", "act_heads", None, None)},
        },
        "k": ("layers", "act_batch", "act_cache", "act_kv", None),
        "v": ("layers", "act_batch", "act_cache", "act_kv", None),
    }


def _zamba_run(cfg, params, x, cache, pos, *, chunked, use_cache, ctx, remat=False):
    """Flat scan over mamba layers; shared attn every attn_every layers."""
    spec = attn_spec(cfg)
    k_every = cfg.ssm.attn_every
    flags = (jnp.arange(cfg.n_layers) % k_every) == 0
    sp = params["shared_attn"]
    ckpt = (
        (lambda f: jax.checkpoint(f, policy=jax.checkpoint_policies.nothing_saveable))
        if remat
        else (lambda f: f)
    )

    @ckpt
    def body(carry, inp):
        x, app_idx, kc_all, vc_all = carry
        mp, mstate, flag = inp

        def with_attn(x, kc_all, vc_all):
            if use_cache:
                kc = kc_all[app_idx]
                vc = vc_all[app_idx]
                xo, kc, vc = _apply_block_cached(
                    cfg, spec, sp, x, kc, vc, pos, local=False, ctx=ctx
                )
                kc_all = kc_all.at[app_idx].set(kc)
                vc_all = vc_all.at[app_idx].set(vc)
            else:
                xo, _, _ = _apply_block_full(cfg, spec, sp, x, local=False, ctx=ctx)
            return xo, kc_all, vc_all

        x, kc_all, vc_all = jax.lax.cond(
            flag,
            with_attn,
            lambda x, k, v: (x, k, v),
            x, kc_all, vc_all,
        )
        app_idx = app_idx + flag.astype(jnp.int32)
        x, new_mstate = S.mamba2_forward(
            mp, x, cfg.d_model, cfg.ssm, cfg.n_heads, mstate, chunked=chunked
        )
        x = ctx.cs(x, ("act_batch", "act_seq" if not use_cache else None, "act_embed"))
        return (x, app_idx, kc_all, vc_all), new_mstate

    kc_all = cache["k"] if use_cache else jnp.zeros((1, 1, 1, 1, 1), jnp.bfloat16)
    vc_all = cache["v"] if use_cache else jnp.zeros((1, 1, 1, 1, 1), jnp.bfloat16)
    (x, _, kc_all, vc_all), new_mamba = loops.scan(
        body, (x, jnp.int32(0), kc_all, vc_all), (params["mamba"], cache["mamba"], flags)
    )
    new_cache = {"mamba": new_mamba, "k": kc_all, "v": vc_all}
    return x, new_cache


def zamba_forward_train(cfg, params, batch, *, ctx=NULL_CTX, remat=False):
    tokens = batch["tokens"]
    x = L.embed(params["embed"], tokens)
    x = ctx.cs(x, ("act_batch", "act_seq", "act_embed"))
    cache = {
        "mamba": zamba_init_cache(cfg, tokens.shape[0], 1)["mamba"],
        "k": None,
        "v": None,
    }
    x, _ = _zamba_run(
        cfg, params, x, cache, 0, chunked=True, use_cache=False, ctx=ctx,
        remat=remat,
    )
    if "targets" in batch:
        loss_sum, n = chunked_ce_loss(cfg, params, x, batch["targets"], ctx=ctx)
        return loss_sum / n.astype(jnp.float32), {}
    return _logits(cfg, params, x), {}


def zamba_prefill(cfg, params, batch, cache, *, ctx=NULL_CTX,
                  last_only: bool = False):
    tokens = batch["tokens"]
    x = L.embed(params["embed"], tokens)
    x = ctx.cs(x, ("act_batch", "act_seq", "act_embed"))
    x, cache = _zamba_run(
        cfg, params, x, cache, 0, chunked=True, use_cache=True, ctx=ctx
    )
    if last_only:
        x = x[:, -1:]
    return _logits(cfg, params, x), cache


def zamba_decode(cfg, params, tokens, cache, pos, *, ctx=NULL_CTX):
    x = L.embed(params["embed"], tokens)
    x, cache = _zamba_run(
        cfg, params, x, cache, pos, chunked=False, use_cache=True, ctx=ctx
    )
    return _logits(cfg, params, x), cache
