"""Failure detection: heartbeat monitor + failure-injection hooks.

At production scale the serving coordinator tracks liveness of (a) edge
devices and (b) verifier replicas.  Both are host-side concerns — no jax
state — so the monitor is a plain event-time bookkeeping structure that the
simulator and the serving server share.

Sessions owned by a dead device are reaped (slots freed); verification
batches in flight on a dead replica are re-dispatched by the
``HedgedDispatcher`` (idempotent by (session, round) key).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class PeerState:
    peer_id: str
    last_beat: float
    alive: bool = True
    missed: int = 0


class HeartbeatMonitor:
    """Declares a peer dead after ``timeout`` without a heartbeat."""

    def __init__(self, *, timeout: float = 5.0, on_death=None, on_rejoin=None):
        self.timeout = timeout
        self.on_death = on_death
        self.on_rejoin = on_rejoin
        self.peers: dict[str, PeerState] = {}
        self.deaths: list[tuple[str, float]] = []
        self.rejoins: list[tuple[str, float]] = []

    def register(self, peer_id: str, now: float):
        self.peers[peer_id] = PeerState(peer_id, last_beat=now)

    def beat(self, peer_id: str, now: float):
        p = self.peers.get(peer_id)
        if p is None:
            self.register(peer_id, now)
            return
        p.last_beat = now
        p.missed = 0
        if not p.alive:  # peer rejoined (elastic scale-up path)
            p.alive = True
            self.rejoins.append((peer_id, now))
            if self.on_rejoin:
                self.on_rejoin(peer_id, now)

    def sweep(self, now: float) -> list[str]:
        """Returns peers newly declared dead at ``now``."""
        newly_dead = []
        for p in self.peers.values():
            if p.alive and now - p.last_beat > self.timeout:
                p.alive = False
                p.missed += 1
                newly_dead.append(p.peer_id)
                self.deaths.append((p.peer_id, now))
                if self.on_death:
                    self.on_death(p.peer_id, now)
        return newly_dead

    def alive_peers(self) -> list[str]:
        return [p.peer_id for p in self.peers.values() if p.alive]

    @property
    def n_alive(self) -> int:
        return sum(p.alive for p in self.peers.values())


@dataclasses.dataclass
class FailurePlan:
    """Deterministic failure injection for tests/simulations:
    [(peer_id, t_fail, t_recover_or_None), ...]."""

    events: list

    def is_up(self, peer_id: str, now: float) -> bool:
        for pid, t_fail, t_rec in self.events:
            if pid == peer_id and now >= t_fail and (t_rec is None or now < t_rec):
                return False
        return True
