"""Straggler mitigation: hedged verification dispatch.

The scheduler predicts every batch's completion time (estimator).  If a
dispatched batch exceeds its ETA by more than ``hedge_factor`` x guard, the
dispatcher re-enqueues the batch's requests to a backup replica.  Commits
are idempotent by (session_id, round_index): whichever replica answers
first wins; the late answer is dropped.

This is the TPU-cluster adaptation of request hedging (tail-at-scale):
verification requests are stateless *given the KV prefix*, and prefix KV is
reconstructable from the committed tokens, so hedging is safe — the backup
replica cold-starts the prefix (cost modeled by the estimator's N_linear
term) and still beats a wedged primary.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional


@dataclasses.dataclass
class InFlight:
    key: tuple                 # (session_id, round_index)
    replica: str
    dispatched_at: float
    eta: float                 # estimator prediction (s)
    hedged: bool = False


class HedgedDispatcher:
    def __init__(
        self,
        replicas: list[str],
        *,
        guard: float = 0.005,
        hedge_factor: float = 3.0,
        on_hedge: Optional[Callable] = None,
    ):
        if not replicas:
            raise ValueError("need at least one replica")
        self.replicas = list(replicas)
        self.guard = guard
        self.hedge_factor = hedge_factor
        self.on_hedge = on_hedge
        self.inflight: dict[tuple, InFlight] = {}
        self.committed: set[tuple] = set()
        self.stats = {"dispatched": 0, "hedged": 0, "dup_commits_dropped": 0}
        self._rr = 0

    # -- replica selection ---------------------------------------------------
    def pick_replica(self, exclude: str | None = None) -> str:
        for _ in range(len(self.replicas)):
            r = self.replicas[self._rr % len(self.replicas)]
            self._rr += 1
            if r != exclude:
                return r
        return self.replicas[0]

    def remove_replica(self, replica: str):
        """Failure path: drop the replica, re-dispatch its inflight work."""
        if replica in self.replicas and len(self.replicas) > 1:
            self.replicas.remove(replica)
        for f in list(self.inflight.values()):
            if f.replica == replica:
                f.replica = self.pick_replica(exclude=replica)
                f.hedged = True
                self.stats["hedged"] += 1

    def add_replica(self, replica: str):
        if replica not in self.replicas:
            self.replicas.append(replica)

    # -- dispatch / commit -----------------------------------------------------
    def dispatch(self, key: tuple, eta: float, now: float) -> str:
        replica = self.pick_replica()
        self.inflight[key] = InFlight(
            key=key, replica=replica, dispatched_at=now, eta=eta
        )
        self.stats["dispatched"] += 1
        return replica

    def sweep(self, now: float) -> list[tuple]:
        """Hedge everything whose ETA has been exceeded by hedge_factor x
        (eta + guard).  Returns the hedged keys (caller re-enqueues them on
        the returned backup replica)."""
        hedged = []
        for f in self.inflight.values():
            deadline = f.dispatched_at + self.hedge_factor * (f.eta + self.guard)
            if not f.hedged and now > deadline:
                f.hedged = True
                backup = self.pick_replica(exclude=f.replica)
                self.stats["hedged"] += 1
                hedged.append((f.key, backup))
                if self.on_hedge:
                    self.on_hedge(f.key, f.replica, backup, now)
        return hedged

    def commit(self, key: tuple) -> bool:
        """True if this is the first (winning) commit for the key."""
        if key in self.committed:
            self.stats["dup_commits_dropped"] += 1
            return False
        self.committed.add(key)
        self.inflight.pop(key, None)
        return True
