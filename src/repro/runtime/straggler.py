"""Straggler mitigation: hedged verification dispatch.

The scheduler predicts every batch's completion time (estimator).  If a
dispatched batch exceeds its ETA by more than ``hedge_factor`` x guard, the
dispatcher re-enqueues the batch's requests to a backup replica.  Commits
are idempotent by (session_id, round_index): whichever replica answers
first wins; the late answer is dropped.

This is the TPU-cluster adaptation of request hedging (tail-at-scale):
verification requests are stateless *given the KV prefix*, and prefix KV is
reconstructable from the committed tokens, so hedging is safe — the backup
replica cold-starts the prefix (cost modeled by the estimator's N_linear
term) and still beats a wedged primary.

Degraded mode: when the last replica dies there is nowhere to re-dispatch.
``remove_replica`` then parks the dead replica's in-flight work in
``orphaned`` and sets ``degraded`` — an explicit signal the caller must
handle (fail the requests, or wait for ``add_replica`` to reclaim them) —
instead of silently "re-dispatching" back to the dead replica.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional


class NoReplicasError(RuntimeError):
    """Raised when a dispatch is requested but no replica is in rotation."""


@dataclasses.dataclass
class InFlight:
    key: tuple                 # (session_id, round_index)
    replica: str
    dispatched_at: float
    eta: float                 # estimator prediction (s)
    hedged: bool = False


class HedgedDispatcher:
    def __init__(
        self,
        replicas: list[str],
        *,
        guard: float = 0.005,
        hedge_factor: float = 3.0,
        on_hedge: Optional[Callable] = None,
    ):
        if not replicas:
            raise ValueError("need at least one replica")
        self.replicas = list(replicas)
        self.guard = guard
        self.hedge_factor = hedge_factor
        self.on_hedge = on_hedge
        self.inflight: dict[tuple, InFlight] = {}
        self.orphaned: dict[tuple, InFlight] = {}
        self.committed: set[tuple] = set()
        self.stats = {"dispatched": 0, "hedged": 0, "dup_commits_dropped": 0,
                      "hedges_skipped": 0, "orphaned": 0}
        self._rr = 0

    # -- replica selection ---------------------------------------------------
    @property
    def degraded(self) -> bool:
        """True when in-flight work is parked with no replica to run it."""
        return bool(self.orphaned) or not self.replicas

    def pick_replica(self, exclude: str | None = None) -> str | None:
        """Next replica in rotation, or ``None`` when every candidate is
        excluded (single-replica fleet hedging against itself, or an empty
        rotation).  Callers must skip the hedge on ``None`` — re-dispatching
        to the excluded primary would just double the wedged work."""
        for _ in range(len(self.replicas)):
            r = self.replicas[self._rr % len(self.replicas)]
            self._rr += 1
            if r != exclude:
                return r
        return None

    def remove_replica(self, replica: str) -> list[tuple]:
        """Failure path: drop the replica from rotation and re-assign its
        in-flight work.  Returns the re-dispatch plan as ``(key, backup)``
        pairs; ``backup is None`` means the work is orphaned (no surviving
        replica — ``degraded`` is now set) and parked in ``orphaned`` until
        ``add_replica`` reclaims it or the caller fails the request."""
        if replica in self.replicas:
            self.replicas.remove(replica)
        plan: list[tuple] = []
        for f in list(self.inflight.values()):
            if f.replica != replica:
                continue
            backup = self.pick_replica(exclude=replica)
            if backup is None:
                del self.inflight[f.key]
                self.orphaned[f.key] = f
                self.stats["orphaned"] += 1
                plan.append((f.key, None))
            else:
                f.replica = backup
                f.hedged = True
                self.stats["hedged"] += 1
                plan.append((f.key, backup))
        return plan

    def add_replica(self, replica: str) -> list[tuple]:
        """Elastic scale-up / rejoin.  Reclaims orphaned work onto the new
        replica and returns it as ``(key, replica)`` re-dispatch pairs."""
        if replica not in self.replicas:
            self.replicas.append(replica)
        plan: list[tuple] = []
        for key, f in list(self.orphaned.items()):
            del self.orphaned[key]
            f.replica = replica
            f.hedged = True
            self.inflight[key] = f
            plan.append((key, replica))
        return plan

    # -- dispatch / commit -----------------------------------------------------
    def dispatch(self, key: tuple, eta: float, now: float) -> str:
        replica = self.pick_replica()
        if replica is None:
            raise NoReplicasError("no replica in rotation")
        self.track(key, replica, eta, now)
        return replica

    def track(self, key: tuple, replica: str, eta: float, now: float):
        """Record an externally-routed dispatch (the fleet router picks the
        replica by session ownership, not round-robin) so ``sweep`` can
        hedge it and ``commit`` can dedup it."""
        self.inflight[key] = InFlight(
            key=key, replica=replica, dispatched_at=now, eta=eta
        )
        self.stats["dispatched"] += 1

    def sweep(self, now: float) -> list[tuple]:
        """Hedge everything whose ETA has been exceeded by hedge_factor x
        (eta + guard).  Returns the hedged keys (caller re-enqueues them on
        the returned backup replica).  Entries with no eligible backup are
        left un-hedged (and re-checked next sweep, so a later rejoin can
        still rescue them)."""
        hedged = []
        for f in self.inflight.values():
            deadline = f.dispatched_at + self.hedge_factor * (f.eta + self.guard)
            if not f.hedged and now > deadline:
                backup = self.pick_replica(exclude=f.replica)
                if backup is None:
                    self.stats["hedges_skipped"] += 1
                    continue
                f.hedged = True
                self.stats["hedged"] += 1
                hedged.append((f.key, backup))
                if self.on_hedge:
                    self.on_hedge(f.key, f.replica, backup, now)
        return hedged

    def commit(self, key: tuple) -> bool:
        """True if this is the first (winning) commit for the key."""
        if key in self.committed:
            self.stats["dup_commits_dropped"] += 1
            return False
        self.committed.add(key)
        self.inflight.pop(key, None)
        self.orphaned.pop(key, None)
        return True
