"""Distributed runtime: checkpoint/restore (elastic), failure detection,
straggler mitigation."""
from repro.runtime.checkpoint import (
    CheckpointManager,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)
from repro.runtime.failure import FailurePlan, HeartbeatMonitor
from repro.runtime.straggler import HedgedDispatcher, NoReplicasError

__all__ = [
    "CheckpointManager",
    "save_checkpoint",
    "restore_checkpoint",
    "latest_step",
    "FailurePlan",
    "HeartbeatMonitor",
    "HedgedDispatcher",
    "NoReplicasError",
]
