"""Sharded, elastic checkpointing.

Layout (one directory per step):

    ckpt_dir/
      step_000100/
        manifest.json            # tree structure, shapes, dtypes, meta
        host_00000.npz           # this host's addressable shards
        host_00001.npz
        ...
      step_000100.tmp-*/         # staging dir (atomic rename commit)

Design points for 1000+ node deployments:

  * **Sharded writes** — each host serializes only the addressable shards
    of every array (`arr.addressable_shards`), so checkpoint bandwidth
    scales with hosts and no host ever materializes the full model.
  * **Atomic commit** — hosts write into a staging dir; host 0 writes the
    manifest last and renames the directory.  A crash mid-save never
    corrupts the previous checkpoint (restore scans for the newest
    *committed* step).
  * **Elastic restore (remesh)** — the manifest stores global shapes, not
    device layouts.  On restore, shards are assembled into full host
    arrays and re-sharded onto the *current* mesh via ``jax.device_put``
    with the caller's shardings, so a job can restart on a different
    device count (scale up/down) or different mesh shape.
  * **Async save** — `CheckpointManager.save(..., blocking=False)` copies
    shards to host RAM synchronously (cheap) and runs file IO on a
    background thread, overlapping with the next train steps.

Dedup: shard files are content-addressed per (host, step) and identical
consecutive arrays could be hard-linked; kept simple here — one npz per
host per step, with `keep` garbage collection.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading

import numpy as np

import jax


# ---------------------------------------------------------------------------
# tree <-> flat helpers
# ---------------------------------------------------------------------------


def _flatten_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(_path_str(p) for p in path)
        out[key] = leaf
    return out


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def _treedef_blueprint(tree):
    """JSON-serializable structure: nested dicts/lists with leaf markers."""

    def rec(x):
        if isinstance(x, dict):
            return {"__kind__": "dict", "items": {k: rec(v) for k, v in x.items()}}
        if isinstance(x, (list, tuple)):
            return {
                "__kind__": "list" if isinstance(x, list) else "tuple",
                "items": [rec(v) for v in x],
            }
        return {"__kind__": "leaf"}

    return rec(tree)


def _rebuild_from_blueprint(bp, leaves_by_key, prefix=()):
    kind = bp["__kind__"]
    if kind == "leaf":
        return leaves_by_key["/".join(prefix)]
    if kind == "dict":
        return {
            k: _rebuild_from_blueprint(v, leaves_by_key, prefix + (k,))
            for k, v in bp["items"].items()
        }
        # insertion order preserved
    seq = [
        _rebuild_from_blueprint(v, leaves_by_key, prefix + (str(i),))
        for i, v in enumerate(bp["items"])
    ]
    return seq if kind == "list" else tuple(seq)


# ---------------------------------------------------------------------------
# save
# ---------------------------------------------------------------------------


def _step_dir(ckpt_dir: str, step: int) -> str:
    return os.path.join(ckpt_dir, f"step_{step:08d}")


_NATIVE_KINDS = set("biufc")


def _to_storable(a: np.ndarray) -> np.ndarray:
    """npz can't round-trip ml_dtypes (bfloat16, fp8); store a same-width
    uint view — the manifest records the true dtype for the way back."""
    if a.dtype.kind in _NATIVE_KINDS:
        return a
    return a.view({1: np.uint8, 2: np.uint16, 4: np.uint32}[a.dtype.itemsize])


def _from_storable(a: np.ndarray, dtype) -> np.ndarray:
    dtype = np.dtype(dtype)
    if a.dtype == dtype:
        return a
    if dtype.kind not in _NATIVE_KINDS and a.dtype.kind == "u":
        return a.view(dtype)
    return a.astype(dtype)


def save_checkpoint(ckpt_dir: str, step: int, tree, *, meta: dict | None = None):
    """Write one committed checkpoint for ``tree`` (pytree of jax/np arrays)."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = _step_dir(ckpt_dir, step)
    staging = tempfile.mkdtemp(prefix=os.path.basename(final) + ".tmp-", dir=ckpt_dir)

    flat = _flatten_with_paths(tree)
    host = jax.process_index()
    shard_blobs = {}
    index = {}
    for key, leaf in flat.items():
        arr = leaf
        if isinstance(arr, jax.Array) and hasattr(arr, "addressable_shards"):
            shards = arr.addressable_shards
            for s in shards:
                sk = f"{key}::{'_'.join(str(x.start or 0) for x in _norm_index(s.index, arr.shape))}"
                shard_blobs[sk] = _to_storable(np.asarray(s.data))
                index.setdefault(key, []).append(
                    {
                        "shard": sk,
                        "start": [x.start or 0 for x in _norm_index(s.index, arr.shape)],
                    }
                )
        else:
            a = np.asarray(arr)
            sk = f"{key}::full"
            shard_blobs[sk] = _to_storable(a)
            index[key] = [{"shard": sk, "start": [0] * a.ndim}]

    np.savez(os.path.join(staging, f"host_{host:05d}.npz"), **shard_blobs)

    if host == 0:
        manifest = {
            "step": step,
            "meta": meta or {},
            "blueprint": _treedef_blueprint(tree),
            "arrays": {
                key: {
                    "shape": list(getattr(leaf, "shape", np.shape(leaf))),
                    "dtype": str(getattr(leaf, "dtype", np.asarray(leaf).dtype)),
                }
                for key, leaf in flat.items()
            },
            "index": {k: v for k, v in index.items()},
            "n_hosts": jax.process_count(),
        }
        with open(os.path.join(staging, "manifest.json"), "w") as f:
            json.dump(manifest, f)
    # commit
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(staging, final)
    return final


def _norm_index(idx, shape):
    out = []
    for sl, n in zip(idx, shape):
        start = sl.start if sl.start is not None else 0
        stop = sl.stop if sl.stop is not None else n
        out.append(slice(start, stop))
    return out


# ---------------------------------------------------------------------------
# restore
# ---------------------------------------------------------------------------


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and ".tmp-" not in name:
            if os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
                steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, *, step: int | None = None, shardings=None):
    """Restore a checkpoint; returns (tree, meta).

    ``shardings``: optional pytree of NamedShardings matching the saved tree
    — enables *elastic* restore onto a different mesh/device count (arrays
    are assembled host-side then re-sharded with ``jax.device_put``).
    Without it, leaves come back as numpy arrays.
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint under {ckpt_dir}")
    d = _step_dir(ckpt_dir, step)
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)

    blobs = {}
    for name in sorted(os.listdir(d)):
        if name.startswith("host_") and name.endswith(".npz"):
            with np.load(os.path.join(d, name)) as z:
                for k in z.files:
                    blobs[k] = z[k]

    leaves = {}
    for key, info in manifest["arrays"].items():
        full = np.zeros(info["shape"], dtype=np.dtype(info["dtype"]))
        for piece in manifest["index"][key]:
            shard = _from_storable(blobs[piece["shard"]], info["dtype"])
            start = piece["start"]
            sl = tuple(slice(s, s + n) for s, n in zip(start, shard.shape))
            full[sl] = shard
        if full.ndim == 0:
            full = full[()]
        leaves[key] = full

    tree = _rebuild_from_blueprint(manifest["blueprint"], leaves)
    if shardings is not None:
        tree = jax.tree.map(
            lambda leaf, sh: jax.device_put(leaf, sh), tree, shardings
        )
    return tree, manifest["meta"]


# ---------------------------------------------------------------------------
# manager (async save + GC)
# ---------------------------------------------------------------------------


class CheckpointManager:
    def __init__(self, ckpt_dir: str, *, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None

    def save(self, step: int, tree, *, meta=None, blocking=True):
        self.wait()  # one in-flight save at a time
        # snapshot to host RAM now so the donated buffers can be reused
        host_tree = jax.tree.map(np.asarray, tree)

        def work():
            try:
                save_checkpoint(self.ckpt_dir, step, host_tree, meta=meta)
                self._gc()
            except BaseException as e:  # surfaced at next wait()
                self._error = e

        if blocking:
            work()
            self._raise_if_failed()
        else:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self._raise_if_failed()

    def _raise_if_failed(self):
        if self._error is not None:
            e, self._error = self._error, None
            raise e

    def restore_latest(self, shardings=None):
        return restore_checkpoint(self.ckpt_dir, shardings=shardings)

    def _gc(self):
        if not os.path.isdir(self.ckpt_dir):
            return
        steps = sorted(
            int(n.split("_")[1])
            for n in os.listdir(self.ckpt_dir)
            if n.startswith("step_") and ".tmp-" not in n
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(_step_dir(self.ckpt_dir, s), ignore_errors=True)
