"""Tenant registry: per-tenant weight, SLO class and budgets (DESIGN.md §13).

A `TenantRegistry` is the server-side source of truth for the multi-
tenant subsystem: each `TenantSpec` carries the tenant's WFQ **weight**,
an optional default **SLO class** for its sessions, and three budgets —

  * a two-stage token bucket (``rate_tokens_per_s`` / ``burst_tokens``,
    `repro.tenancy.ratelimit`) metering admitted tokens;
  * ``max_concurrency`` — live sessions (active + prefilling + capacity-
    queued) the tenant may hold at once;
  * ``max_tokens_in_flight`` — drafted tokens submitted but not yet
    committed;
  * ``max_queued`` — throttle-held session opens before new opens are
    rejected outright (the REJECT stage; None = queue unboundedly).

The registry is mechanism, not policy: `WISPServer` asks it to price an
``open_session`` / ``submit`` (`admit_session` / `admit_block`) and owns
the throttle buffers and event emission; the ``"wfq"`` scheduling policy
reads only the per-item ``tenant_weight`` stamped from here.  One
registry instance may be shared across a verifier fleet — budgets are
then tenant-global, which is what a fleet-wide SLO means.

The ``"default"`` tenant always exists and is unlimited (weight 1.0, no
bucket, no budgets), so a server constructed without tenants behaves
exactly as before the subsystem existed.  Unknown tenant names raise a
`ValueError` listing the registered names (never a bare KeyError).
"""
from __future__ import annotations

import dataclasses

from repro.tenancy.ratelimit import Stage, TokenBucket

#: the implicit tenant every untagged session belongs to
DEFAULT_TENANT = "default"


@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """One tenant's static contract: fair-share weight, SLO default and
    admission budgets.  ``None`` budgets are unlimited."""

    tenant: str
    weight: float = 1.0
    #: default SLO class for sessions opened without an explicit one
    slo_class: int | None = None
    #: sustained token-bucket refill (tokens/virtual-second); None = no limit
    rate_tokens_per_s: float | None = None
    burst_tokens: float = 512.0
    max_tokens_in_flight: int | None = None
    max_concurrency: int | None = None
    #: throttle-held session opens before REJECT; None = queue unboundedly
    max_queued: int | None = None

    def __post_init__(self):
        if self.weight <= 0:
            raise ValueError(
                f"tenant {self.tenant!r}: weight must be > 0, "
                f"got {self.weight}"
            )

    @classmethod
    def parse(cls, text: str) -> "TenantSpec":
        """Parse a CLI spec: ``name[:key=value]*`` with keys ``weight``,
        ``slo``, ``rate``, ``burst``, ``inflight``, ``conc``, ``queued``
        (e.g. ``flood:weight=1:rate=600:burst=128:conc=4:queued=2``)."""
        parts = text.split(":")
        name, kvs = parts[0], parts[1:]
        if not name:
            raise ValueError(f"tenant spec needs a name: {text!r}")
        keys = {
            "weight": ("weight", float),
            "slo": ("slo_class", int),
            "rate": ("rate_tokens_per_s", float),
            "burst": ("burst_tokens", float),
            "inflight": ("max_tokens_in_flight", int),
            "conc": ("max_concurrency", int),
            "queued": ("max_queued", int),
        }
        kwargs: dict = {}
        for kv in kvs:
            k, _, v = kv.partition("=")
            if k not in keys or not v:
                raise ValueError(
                    f"bad tenant spec field {kv!r} in {text!r}; known "
                    f"fields: {sorted(keys)}"
                )
            field, cast = keys[k]
            kwargs[field] = cast(v)
        return cls(tenant=name, **kwargs)


@dataclasses.dataclass
class TenantState:
    """Live accounting for one tenant (registry-owned, server-updated)."""

    spec: TenantSpec
    bucket: TokenBucket
    #: sessions currently admitted or capacity-queued on the server(s)
    live_sessions: int = 0
    #: drafted tokens submitted but not yet committed/purged
    tokens_in_flight: int = 0
    # observability counters
    throttled: int = 0                 # DEPRIORITIZE + QUEUE decisions
    rejected: int = 0
    submitted_tokens: int = 0
    committed_tokens: int = 0


class TenantRegistry:
    """Tenant name -> `TenantState`; see module docstring."""

    def __init__(self, specs=()):
        self._tenants: dict[str, TenantState] = {}
        self.register(TenantSpec(DEFAULT_TENANT))
        for spec in specs:
            if isinstance(spec, str):
                spec = TenantSpec.parse(spec)
            self.register(spec)

    def register(self, spec: TenantSpec) -> TenantState:
        st = TenantState(
            spec=spec,
            bucket=TokenBucket(rate=spec.rate_tokens_per_s,
                               burst=spec.burst_tokens),
        )
        self._tenants[spec.tenant] = st
        return st

    def names(self) -> list[str]:
        return sorted(self._tenants)

    def get(self, tenant: str) -> TenantState:
        try:
            return self._tenants[tenant]
        except KeyError:
            raise ValueError(
                f"unknown tenant {tenant!r}; registered: {self.names()}"
            ) from None

    def weight(self, tenant: str) -> float:
        return self.get(tenant).spec.weight

    def __contains__(self, tenant: str) -> bool:
        return tenant in self._tenants

    def __iter__(self):
        return iter(sorted(self._tenants))

    # -- admission pricing (the server calls these) -------------------------
    def admit_session(self, tenant: str, cost: float, now: float, *,
                      queued: int = 0) -> Stage:
        """Price an ``open_session`` of ``cost`` prompt tokens.  Budget
        checks run BEFORE the bucket so an escalated decision never
        leaves a spurious charge behind (the throttle-release retry would
        otherwise double-charge).  ``queued`` is the tenant's current
        throttle-held open backlog — past ``max_queued`` the open is
        rejected outright (shedding bounds both the backlog and the
        bucket's debt)."""
        st = self.get(tenant)
        spec = st.spec
        if spec.max_queued is not None and queued >= spec.max_queued:
            st.rejected += 1
            return Stage.REJECT
        if (spec.max_concurrency is not None
                and st.live_sessions >= spec.max_concurrency):
            st.throttled += 1
            return Stage.QUEUE
        stage = st.bucket.decide(cost, now)
        if stage != Stage.ADMIT:
            st.throttled += 1
        return stage

    def admit_block(self, tenant: str, cost: float, now: float) -> Stage:
        """Price a ``submit`` of ``cost`` draft-block tokens.  Clamped to
        QUEUE — a streaming session's block is never dropped, only
        deprioritized or held until the bucket recovers."""
        st = self.get(tenant)
        spec = st.spec
        if (spec.max_tokens_in_flight is not None
                and st.tokens_in_flight + cost > spec.max_tokens_in_flight):
            st.throttled += 1
            return Stage.QUEUE
        stage = st.bucket.decide(cost, now)
        if stage != Stage.ADMIT:
            st.throttled += 1
        return min(stage, Stage.QUEUE)

    # -- observability ------------------------------------------------------
    def snapshot(self) -> dict:
        """Per-tenant counter snapshot (weights + live accounting)."""
        return {
            name: {
                "weight": st.spec.weight,
                "live_sessions": st.live_sessions,
                "tokens_in_flight": st.tokens_in_flight,
                "throttled": st.throttled,
                "rejected": st.rejected,
                "submitted_tokens": st.submitted_tokens,
                "committed_tokens": st.committed_tokens,
            }
            for name, st in sorted(self._tenants.items())
        }
