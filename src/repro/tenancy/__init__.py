"""Multi-tenant serving subsystem (DESIGN.md §13).

Layered over the policy registry and the typed event API: a
`TenantRegistry` of per-tenant weights / SLO classes / budgets, a
two-stage token-bucket rate limiter (deprioritize -> queue -> reject)
applied at ``open_session`` / ``submit``, and — in
`repro.core.scheduler` — the ``"wfq"`` weighted-fair-queueing policy
that consumes the tenant weights these specs define.
"""
from __future__ import annotations

from repro.tenancy.ratelimit import Stage, TokenBucket
from repro.tenancy.registry import (
    DEFAULT_TENANT,
    TenantRegistry,
    TenantSpec,
    TenantState,
)

__all__ = [
    "DEFAULT_TENANT",
    "Stage",
    "TenantRegistry",
    "TenantSpec",
    "TenantState",
    "TokenBucket",
]
