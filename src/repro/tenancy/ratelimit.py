"""Two-stage token-bucket rate limiting (DESIGN.md §13).

One `TokenBucket` per tenant meters admitted work in *tokens* (prompt
tokens at ``open_session``, draft-block tokens at ``submit``).  The
bucket refills lazily at ``rate`` tokens per virtual second up to
``burst``; a charge may push the level *negative* down to
``-deprioritize_debt`` — that borrow band is the first throttle stage.
The decision a charge gets is a pure function of the (refilled) level,
so severity is monotone as the level drops:

  * ``ADMIT``        — the bucket covers the cost (post-charge level
                       >= 0): full-weight service;
  * ``DEPRIORITIZE`` — the cost is borrowed from the debt band: the work
                       runs, but flagged ``deprioritized`` so the WFQ
                       policy serves it at a fraction of the tenant's
                       weight;
  * ``QUEUE``        — even the debt band cannot cover it: the bucket is
                       NOT charged and the caller must hold the work
                       until a later ``decide`` admits it (the server's
                       per-tenant throttle buffer, released each epoch).

The fourth stage, ``REJECT``, is a *backlog* decision, not a level
decision: `TenantRegistry.admit_session` escalates ``QUEUE`` to
``REJECT`` when the tenant's held-session backlog already exceeds its
``max_queued`` budget.  Backlog grows monotonically with arrival rate,
so the full deprioritize -> queue -> reject ladder is monotone in
offered load (tests/test_tenancy.py pins this property).  Rejection
applies only to session opens — a streaming session's submitted block is
never dropped, only deprioritized or held.

``rate=None`` means unlimited: every decision is ``ADMIT`` and the
bucket never charges — attaching a default `TenantRegistry` to a server
is therefore behavior-neutral (the golden ``tenant/*`` cells pin this).
"""
from __future__ import annotations

import dataclasses
import enum


class Stage(enum.IntEnum):
    """Rate-limiter decision, ordered by severity (monotone in load)."""

    ADMIT = 0
    DEPRIORITIZE = 1
    QUEUE = 2
    REJECT = 3


@dataclasses.dataclass
class TokenBucket:
    """Lazily-refilled token bucket with a borrow (deprioritize) band.

    Level invariant: ``-deprioritize_debt <= level <= burst`` — QUEUE
    decisions never charge, so debt is bounded and tokens admitted at
    full weight over any window ``T`` are bounded by
    ``burst + rate * T`` (the classic bucket bound; property-tested)."""

    #: sustained refill rate, tokens per (virtual) second; None = unlimited
    rate: float | None
    #: bucket capacity — the burst admitted at full weight from idle
    burst: float = 512.0
    #: how far below zero a charge may borrow (the DEPRIORITIZE band);
    #: None defaults to ``burst``
    deprioritize_debt: float | None = None
    level: float = dataclasses.field(init=False, default=0.0)
    _t: float = dataclasses.field(init=False, default=0.0)

    def __post_init__(self):
        if self.deprioritize_debt is None:
            self.deprioritize_debt = float(self.burst)
        self.level = float(self.burst)

    def refill(self, now: float) -> None:
        """Lazy refill: credit ``rate`` tokens/s since the last touch
        (time never runs backwards — out-of-order probes are clamped)."""
        if self.rate is None:
            return
        if now > self._t:
            self.level = min(float(self.burst),
                             self.level + (now - self._t) * self.rate)
        self._t = max(self._t, now)

    def peek(self, now: float) -> float:
        self.refill(now)
        return float("inf") if self.rate is None else self.level

    def decide(self, cost: float, now: float) -> Stage:
        """Charge ``cost`` tokens if any band covers it and return the
        stage; QUEUE leaves the bucket untouched (the caller retries)."""
        self.refill(now)
        if self.rate is None:
            return Stage.ADMIT
        cost = max(float(cost), 0.0)
        if self.level - cost >= 0.0:
            self.level -= cost
            return Stage.ADMIT
        if self.level - cost >= -self.deprioritize_debt:
            self.level -= cost
            return Stage.DEPRIORITIZE
        return Stage.QUEUE
