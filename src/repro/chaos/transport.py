"""`FaultyTransport`: a `NetworkModel` wrapped in a `FaultSchedule`.

Where `NetworkModel` answers "how long does this message take", the
faulty transport answers "when does each *copy* of this message arrive,
if at all": a message keyed ``(session_id, round, attempt)`` is dropped,
duplicated, held back (reordered past later traffic), spiked, or lost to
a link-down window, per the schedule's per-direction `LinkFaults`.

Determinism is the whole point (DESIGN.md §14): each message's fate is
drawn from ``np.random.default_rng((seed, dircode, *key))`` — a fresh
generator seeded by the message's identity — so fates are independent of
event-loop order, retries of the same round draw *fresh* fates (the
attempt index is in the key, which is what makes retry-until-delivered
terminate: P[all attempts drop] -> 0), and the same schedule replayed
over the same run fails identically, byte for byte.
"""
from __future__ import annotations

import numpy as np

from repro.chaos.schedule import FaultSchedule

#: direction -> rng stream code (distinct odd constants so up/down fates
#: of the same (sid, round, attempt) never collide)
_DIRCODE = {"up": 11, "down": 13}


class FaultyTransport:
    """Per-link fault sampler over a wrapped `NetworkModel`.

    ``net`` prices latency (including its own seeded jitter);
    ``schedule`` supplies the fault law.  ``stats`` counts injected
    fates for observability/tests."""

    def __init__(self, net, schedule: FaultSchedule):
        self.net = net
        self.schedule = schedule
        if schedule.seed is None:
            raise ValueError("FaultyTransport needs a resolved schedule "
                             "(seed set; see resolve_fault_schedule)")
        self.stats = {
            "up_sent": 0, "up_dropped": 0, "up_dup": 0, "up_delayed": 0,
            "up_window_drops": 0,
            "down_sent": 0, "down_dropped": 0, "down_dup": 0,
            "down_delayed": 0, "down_window_drops": 0,
        }

    # -- core fate sampler --------------------------------------------------
    def deliveries(self, direction: str, key: tuple, t_send: float,
                   latency: float) -> list[float]:
        """Arrival times for every surviving copy of one message.

        ``direction`` is ``"up"`` | ``"down"``; ``key`` is the message
        identity ``(session_id, round, attempt)`` (non-negative ints);
        ``latency`` is the fault-free transit time the caller priced on
        its `NetworkModel`.  Returns ``[]`` (dropped), one time, or two
        times (duplicated); times are ``>= t_send + latency``."""
        f = self.schedule.up if direction == "up" else self.schedule.down
        st = self.stats
        st[f"{direction}_sent"] += 1
        if f.is_down(t_send):
            st[f"{direction}_window_drops"] += 1
            st[f"{direction}_dropped"] += 1
            return []
        g = np.random.default_rng(
            (int(self.schedule.seed), _DIRCODE[direction],
             *(int(k) for k in key))
        )
        if f.drop and g.random() < f.drop:
            st[f"{direction}_dropped"] += 1
            return []
        delay = 0.0
        if f.spike and g.random() < f.spike:
            delay += f.spike_s
        if f.reorder and g.random() < f.reorder:
            delay += f.reorder_delay
        if delay:
            st[f"{direction}_delayed"] += 1
        out = [t_send + latency + delay]
        if f.dup and g.random() < f.dup:
            st[f"{direction}_dup"] += 1
            out.append(out[0] + f.dup_gap)
        return out

    # -- NetworkModel-shaped conveniences -----------------------------------
    def uplink_deliveries(self, t_send: float, n_draft_tokens: int,
                          q="modelled", *, key: tuple,
                          net_key=None) -> list[float]:
        """Fates + latency for one drafted block on the wrapped net."""
        lat = self.net.uplink_time(n_draft_tokens, q, key=net_key)
        return self.deliveries("up", key, t_send, lat)

    def downlink_deliveries(self, t_send: float, *, key: tuple,
                            net_key=None) -> list[float]:
        """Fates + latency for one verdict on the wrapped net."""
        lat = self.net.downlink_time(key=net_key)
        return self.deliveries("down", key, t_send, lat)
