"""Deterministic chaos injection for the edge-link fault domain.

`repro.chaos` is the single source of fault truth for the serving stack
(DESIGN.md §14): a seeded `FaultSchedule` describes *what goes wrong* —
per-direction message drop / duplication / reordering / latency spikes,
link-down windows, verifier kills and straggle windows — and a
`FaultyTransport` samples each message's fate from a key-derived rng so
the same schedule replayed against the same run produces byte-identical
failures.  The legacy ad-hoc knobs (`ClusterConfig.fail_at` /
``straggle``, ``--fail-at`` / ``--straggle``) compile onto it via
`resolve_fault_schedule`.
"""
from repro.chaos.schedule import (
    FAULT_PRESETS,
    FaultSchedule,
    LinkFaults,
    parse_fault_schedule,
    resolve_fault_schedule,
)
from repro.chaos.transport import FaultyTransport

__all__ = [
    "FAULT_PRESETS",
    "FaultSchedule",
    "FaultyTransport",
    "LinkFaults",
    "parse_fault_schedule",
    "resolve_fault_schedule",
]
