"""Seeded fault schedules: the declarative half of the chaos subsystem.

A `FaultSchedule` is a frozen description of everything that goes wrong
during a run — per-direction edge-link faults (`LinkFaults`) plus the
verifier-side kill/straggle windows PR 6 introduced — and it is pure
*data*: sampling happens in `repro.chaos.transport.FaultyTransport`,
keyed by ``(schedule.seed, direction, session, round, attempt)`` so a
message's fate is a function of its identity, not of event-loop order.

Schedules come from three places, merged by `resolve_fault_schedule`:

  * the DSL (``--fault-schedule``), a comma-separated spec::

        drop=0.1,dup=0.05,reorder=0.05,linkdown@0.25+0.5,seed=7
        up.drop=0.2,down.spike=0.1,spike_s=0.08
        kill=0@0.12+0.38,straggle=1@0.05+0.95*400

    Unprefixed link knobs apply to BOTH directions; ``up.`` / ``down.``
    scope one.  ``linkdown@T0+DUR`` opens a hard outage window (every
    message sent inside it is lost).  ``kill=IDX@T0[+DUR]`` and
    ``straggle=IDX@T0+DUR*FACTOR`` are the verifier fault domain.
  * named presets (`FAULT_PRESETS`) — canned schedules the CI smoke and
    the acceptance gate use by name;
  * the legacy knobs ``ClusterConfig.fail_at`` / ``straggle`` (and their
    CLI flags), which are deprecation shims compiling onto the schedule.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class LinkFaults:
    """Fault law for ONE direction of the edge<->server link.

    Probabilities are per message; delays are seconds.  ``reorder``
    holds a message back by ``reorder_delay`` so traffic sent after it
    can overtake it (deliveries are *not* FIFO under reordering);
    ``spike`` models a transient latency spike of ``spike_s``.  A
    message sent inside a ``windows`` interval is lost outright —
    link-down is a property of the send instant, matching a radio
    dropout (the bits already in flight are the ones that die)."""

    drop: float = 0.0
    dup: float = 0.0
    reorder: float = 0.0
    spike: float = 0.0
    reorder_delay: float = 0.02
    spike_s: float = 0.05
    dup_gap: float = 0.002         # duplicate trails the original by this
    windows: tuple = ()            # ((t0, t1), ...) link-down intervals

    def is_down(self, t: float) -> bool:
        return any(t0 <= t < t1 for (t0, t1) in self.windows)

    def any(self) -> bool:
        return bool(self.drop or self.dup or self.reorder or self.spike
                    or self.windows)


@dataclasses.dataclass(frozen=True)
class FaultSchedule:
    """One run's complete, seeded fault plan (see module docstring).

    ``seed=None`` means "inherit the run seed" — `resolve_fault_schedule`
    fills it from `ClusterConfig.seed` so chaos reproducibility rides the
    same knob as everything else unless pinned explicitly."""

    seed: int | None = None
    up: LinkFaults = LinkFaults()
    down: LinkFaults = LinkFaults()
    #: (verifier_index, t_fail, t_recover_or_None) — FailurePlan rows
    verifier_fail: tuple = ()
    #: (verifier_index, t0, t1, factor) — epoch-slowdown windows
    verifier_straggle: tuple = ()

    def has_link_faults(self) -> bool:
        return self.up.any() or self.down.any()

    def has_verifier_faults(self) -> bool:
        return bool(self.verifier_fail or self.verifier_straggle)


#: named canned schedules (CI + acceptance gates).  "flap" is the
#: acceptance-criteria schedule: 10% drop + duplication + reordering on
#: both directions plus one 500 ms hard outage.
FAULT_PRESETS: dict[str, str] = {
    "lossy": "drop=0.1,dup=0.05,reorder=0.05,seed=7",
    "flap": "drop=0.1,dup=0.05,reorder=0.05,linkdown@0.25+0.5,seed=7",
    "storm": ("drop=0.25,dup=0.1,reorder=0.1,spike=0.15,spike_s=0.08,"
              "linkdown@0.2+0.5,seed=7"),
}

_LINK_FIELDS = {
    "drop", "dup", "reorder", "spike",
    "reorder_delay", "spike_s", "dup_gap",
}


def _set_link(fields: dict, scope: str, key: str, value: float) -> None:
    for d in (("up", "down") if scope == "both" else (scope,)):
        fields[d][key] = value


def _add_window(fields: dict, scope: str, t0: float, t1: float) -> None:
    for d in (("up", "down") if scope == "both" else (scope,)):
        fields[d]["windows"] = tuple(fields[d].get("windows", ())) \
            + ((t0, t1),)


def _parse_at(spec: str) -> tuple[float, float | None]:
    """``T0`` or ``T0+DUR`` -> (t0, t1_or_None)."""
    if "+" in spec:
        t0, dur = spec.split("+", 1)
        return float(t0), float(t0) + float(dur)
    return float(spec), None


def parse_fault_schedule(spec) -> FaultSchedule:
    """Resolve ``spec`` — None, a ready `FaultSchedule`, a preset name,
    or a DSL string — into a `FaultSchedule`."""
    if spec is None:
        return FaultSchedule()
    if isinstance(spec, FaultSchedule):
        return spec
    if not isinstance(spec, str):
        raise TypeError(
            f"fault schedule must be None, a FaultSchedule, a preset name "
            f"or a DSL string; got {type(spec).__name__}"
        )
    spec = FAULT_PRESETS.get(spec.strip(), spec)
    seed: int | None = None
    fields: dict[str, dict] = {"up": {}, "down": {}}
    kills: list[tuple] = []
    straggles: list[tuple] = []
    for raw in spec.split(","):
        tok = raw.strip()
        if not tok:
            continue
        scope = "both"
        if tok.startswith(("up.", "down.")):
            scope, tok = tok.split(".", 1)
        try:
            if tok.startswith("linkdown@"):
                t0, t1 = _parse_at(tok[len("linkdown@"):])
                if t1 is None:
                    raise ValueError("linkdown needs a duration: T0+DUR")
                _add_window(fields, scope, t0, t1)
            elif tok.startswith("kill="):
                idx, at = tok[len("kill="):].split("@", 1)
                t0, t1 = _parse_at(at)
                kills.append((int(idx), t0, t1))
            elif tok.startswith("straggle="):
                idx, rest = tok[len("straggle="):].split("@", 1)
                at, factor = rest.split("*", 1)
                t0, t1 = _parse_at(at)
                if t1 is None:
                    raise ValueError("straggle needs a duration: T0+DUR")
                straggles.append((int(idx), t0, t1, float(factor)))
            elif tok.startswith("seed="):
                seed = int(tok[len("seed="):])
            elif "=" in tok:
                key, val = tok.split("=", 1)
                if key not in _LINK_FIELDS:
                    raise ValueError(f"unknown fault knob {key!r}")
                _set_link(fields, scope, key, float(val))
            else:
                raise ValueError(f"unparseable token {tok!r}")
        except ValueError as e:
            raise ValueError(
                f"bad fault-schedule token {raw.strip()!r}: {e}"
            ) from None
    return FaultSchedule(
        seed=seed,
        up=LinkFaults(**fields["up"]),
        down=LinkFaults(**fields["down"]),
        verifier_fail=tuple(kills),
        verifier_straggle=tuple(straggles),
    )


def resolve_fault_schedule(cfg) -> FaultSchedule:
    """The one place a runtime turns config into a fault plan: parse
    ``cfg.fault_schedule``, fold in the legacy ``cfg.fail_at`` /
    ``cfg.straggle`` verifier knobs (deprecation shims — they compile
    onto the schedule, so old configs keep working unchanged), and
    default the schedule seed from the run seed."""
    sched = parse_fault_schedule(getattr(cfg, "fault_schedule", None))
    vf = tuple(sched.verifier_fail) + tuple(
        (int(i), float(t0), None if t1 is None else float(t1))
        for (i, t0, t1) in getattr(cfg, "fail_at", ())
    )
    vs = tuple(sched.verifier_straggle) + tuple(
        (int(i), float(t0), float(t1), float(f))
        for (i, t0, t1, f) in getattr(cfg, "straggle", ())
    )
    seed = sched.seed if sched.seed is not None else int(getattr(cfg, "seed", 0))
    return dataclasses.replace(
        sched, seed=seed, verifier_fail=vf, verifier_straggle=vs,
    )
