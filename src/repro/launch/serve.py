"""Serving driver: end-to-end WISP loop (drafting edges + verification
server) on real models.

Functionally complete on CPU with reduced configs: N edge devices run draft
models with the intelligent drafting controller; the server batches
verification with the SLO-aware scheduler; PagedAttention-style slot cache +
prefix reuse on the engine.  Paper-scale capacity numbers come from
``repro.sim`` (same control logic, analytic latency model).

Example:
  python -m repro.launch.serve --target qwen2-7b --draft qwen2-7b \\
      --reduced --devices 4 --rounds 8 --scheduler slo
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core.estimator import analytic_tpu_coeffs
from repro.core.predictor import RejectionPredictor
from repro.core.wdt import IterationLog, WDTStats
from repro.models import build
from repro.serving.client import EdgeDevice
from repro.serving.engine import VerificationEngine
from repro.serving.server import WISPServer
from repro.serving.transport import NetworkModel


def run_serving(
    target_arch: str = "qwen2-7b",
    draft_arch: str | None = None,
    *,
    reduced: bool = True,
    devices: int = 4,
    rounds: int = 8,
    k_max: int = 6,
    scheduler: str = "slo",
    predictor: RejectionPredictor | None = None,
    prompt_len: int = 8,
    max_len: int = 512,
    seed: int = 0,
    verbose: bool = True,
):
    tcfg = get_config(target_arch)
    dcfg = get_config(draft_arch or target_arch)
    if reduced:
        tcfg, dcfg = tcfg.reduced(), dcfg.reduced()
    if dcfg.vocab != tcfg.vocab:
        raise ValueError("draft/target vocab mismatch")

    tb, db = build(tcfg), build(dcfg)
    tparams = tb.init(jax.random.PRNGKey(seed))
    dparams = db.init(jax.random.PRNGKey(seed + 1))

    engine = VerificationEngine(tcfg, tparams, max_slots=devices, max_len=max_len)
    coeffs = analytic_tpu_coeffs(tcfg)
    net = NetworkModel()
    server = WISPServer(engine, coeffs, scheduler=scheduler, network=net)

    rng = np.random.default_rng(seed)
    edges, stats = [], []
    for i in range(devices):
        dev = EdgeDevice(
            dcfg, dparams, predictor=predictor, k_max=k_max,
            max_len=max_len, seed=seed + 10 + i,
            draft_speed=float(rng.choice([30.0, 50.0, 80.0])),
        )
        prompt = rng.integers(2, tcfg.vocab, size=prompt_len).tolist()
        slo_class = int(rng.integers(1, 5))
        # synchronous driver: every device must be admitted up front, so
        # fail loudly on capacity exhaustion instead of queueing
        first = server.open_session(i, prompt, slo_class=slo_class,
                                    draft_speed=dev.controller.draft_speed,
                                    queue_on_full=False)
        dev.start_session(i, prompt, first)
        edges.append(dev)
        stats.append(WDTStats())

    now = 0.0
    t_wall0 = time.time()
    for r in range(rounds):
        # all devices draft and submit (synchronous round model on CPU)
        results = {}
        for i, dev in enumerate(edges):
            res = dev.draft_round()
            t_net = net.round_trip(res.n_sent)
            server.submit(i, res.tokens, res.q_logits, now=now,
                          t_draft=res.draft_time, t_network=t_net)
            results[i] = (res, t_net)
        # dispatch epochs until the pool drains
        while server.queue_depth:
            verdicts = server.step(now)
            if not verdicts:
                now += 0.005   # idle epoch: advance time to unblock criticals
                continue
            for v in verdicts:
                res, t_net = results[v.session_id]
                edges[v.session_id].apply_verdict(
                    v.accept_len, v.token, res.tokens
                )
                stats[v.session_id].add(
                    IterationLog(
                        session_id=v.session_id,
                        round_index=r,
                        n_drafted=res.n_drafted,
                        n_sent=res.n_sent,
                        n_accepted=v.accept_len,
                        n_committed=v.emitted,
                        t_draft=res.draft_time,
                        t_network=t_net,
                        t_queue=v.t_queue,
                        t_verify=v.t_verify,
                        violated=v.violated,
                    ),
                    tau_d=1.0 / edges[v.session_id].controller.draft_speed,
                )
            now += 0.01
    wall = time.time() - t_wall0

    total = WDTStats()
    for i, s in enumerate(stats):
        total.iterations += s.iterations
        total.drafted += s.drafted
        total.sent += s.sent
        total.accepted += s.accepted
        total.committed += s.committed
        total.wasted += s.wasted
        total.violations += s.violations
    if verbose:
        print(f"[serve] devices={devices} rounds={rounds} scheduler={scheduler}")
        print(f"[serve] drafted={total.drafted} accepted={total.accepted} "
              f"committed={total.committed} waste_frac={total.waste_fraction:.3f} "
              f"acceptance={total.acceptance_rate:.3f}")
        print(f"[serve] engine batches={engine.stats['batches']} wall={wall:.1f}s")
        for i, dev in enumerate(edges[:4]):
            print(f"[serve] dev{i} response: {dev.response_tokens[:12]}")
    return {"stats": stats, "total": total, "edges": edges, "server": server}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--target", default="qwen2-7b")
    ap.add_argument("--draft", default=None)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--devices", type=int, default=4)
    ap.add_argument("--rounds", type=int, default=8)
    ap.add_argument("--k-max", type=int, default=6)
    ap.add_argument("--scheduler", choices=("slo", "fcfs"), default="slo")
    ap.add_argument("--predictor-path", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    pred = RejectionPredictor.load(args.predictor_path) if args.predictor_path else None
    run_serving(
        args.target, args.draft, devices=args.devices, rounds=args.rounds,
        k_max=args.k_max, scheduler=args.scheduler, predictor=pred,
        seed=args.seed,
    )


if __name__ == "__main__":
    main()
