"""Serving driver: end-to-end WISP loop (drafting edges + verification
server) on real models.

Two drive modes over the same models, workload and scheduler:

  * **event-driven** (default) — `repro.cluster.ClusterRuntime`: per-device
    virtual clocks, drafting overlapped with in-flight verification
    (speculative continue, commit-or-rollback), server dispatch epochs on
    their own timer, transport delays from NetworkModel.  WDT, queueing and
    per-class violations are *measured* from the interleaved execution.
  * **lock-step** (``sync=True`` / ``--sync``) — the original synchronous
    round loop: every device drafts, every request verifies, repeat.  WDT
    can only be accounted analytically here, but the mode is the reference
    the event-driven stream-equivalence guarantee is checked against.

Both commit byte-identical per-session token streams for the same seed
(position-folded draft keys + per-request verification keys).

Example:
  python -m repro.launch.serve --target qwen2-7b --draft qwen2-7b \\
      --reduced --devices 4 --rounds 8 --policy wisp
  python -m repro.launch.serve --devices 4 --rounds 8 --policy edf
  python -m repro.launch.serve --devices 4 --rounds 8 --sync   # lock-step
"""
from __future__ import annotations

import argparse
import time
import warnings

import jax

from repro.cluster import ClusterConfig, ClusterRuntime, build_fleet
from repro.cluster.workload import TENANT_MIXES, build_tenant_registry
from repro.configs import get_config
from repro.core.estimator import EstimatorCoeffs, analytic_tpu_coeffs
from repro.core.scheduler import available_policies
from repro.core.speculation import available_spec_policies
from repro.core.predictor import RejectionPredictor
from repro.core.wdt import IterationLog, WDTStats
from repro.models import build
from repro.serving.client import EdgeDevice
from repro.serving.engine import VerificationEngine
from repro.serving.server import WISPServer
from repro.serving.transport import NetworkModel


def run_serving(
    target_arch: str = "qwen2-7b",
    draft_arch: str | None = None,
    *,
    reduced: bool = True,
    devices: int = 4,
    rounds: int = 8,
    k_max: int = 6,
    policy: str = "wisp",
    scheduler: str | None = None,       # DEPRECATED alias of ``policy``
    predictor: RejectionPredictor | None = None,
    prompt_len: int = 8,
    max_len: int = 512,
    seed: int = 0,
    verbose: bool = True,
    sync: bool = False,
    speculate: bool = True,
    greedy: bool = False,
    churn: bool = False,
    horizon: float | None = None,
    draft_speeds: tuple = (30.0, 50.0, 80.0),
    spec_policy: str = "static",
    link_rtts: tuple = (),
    coeffs: EstimatorCoeffs | None = None,
    dispatch_interval: float = 0.004,
    slo_speeds: dict | None = None,
    sched_cfg=None,
    self_draft: bool = False,
    method: str = "residual",
    q_mode: str = "dense",
    q_top_c: int = 64,
    prefill_mode: str = "zero",
    prefill_chunk_tokens: int = 32,
    ttft_slo: dict | None = None,
    think_time_mean: float = 0.25,
    response_len_mean: float = 24.0,
    verifiers: int = 1,
    fail_at: tuple = (),
    straggle: tuple = (),
    fault_schedule=None,
    link_timeout: float | None = None,
    link_backoff: float = 2.0,
    link_degrade: bool = False,
    link_jitter: float = 0.0,
    heartbeat_interval: float = 0.05,
    heartbeat_timeout: float = 0.15,
    hedge_factor: float = 8.0,
    hedge_guard: float = 0.01,
    kv_tier_pages: int = 0,
    spill_quantize: bool = False,
    spill_idle_epochs: int = 2,
    tenants=None,
    tenant_mix=None,
):
    """Run the WISP serving stack; returns a dict with per-device ``stats``,
    aggregate ``total``, the ``edges`` / ``server`` objects and — in
    event-driven mode — the ``ClusterResult`` under ``"result"``.

    ``policy`` selects the server's batch-selection rule from the
    scheduling-policy registry (``repro.core.scheduler``): ``"wisp"``
    (Algorithm 1; legacy alias ``"slo"``), ``"fcfs"``, ``"edf"``,
    ``"priority"``.  ``spec_policy`` selects each edge device's
    draft-length controller from the speculation registry
    (``repro.core.speculation``): ``"static"`` (fixed K = k_max) or
    ``"adaptive"`` (per-block K from acceptance, RTT and verifier load,
    DESIGN.md §11).  ``link_rtts`` gives devices heterogeneous link base
    RTTs (cycled round-robin, like ``draft_speeds``).

    Edge-link fault domain (DESIGN.md §14): ``fault_schedule`` injects a
    seeded chaos plan (a `repro.chaos.FaultSchedule`, a preset name or a
    DSL string) on every device's uplink/downlink; ``link_timeout``
    arms the edge's per-round retry/backoff loop (idempotent under the
    ``(session_id, round_index)`` key); ``link_degrade`` lets link
    health shrink speculation depth (K=1 while the link is down);
    ``link_jitter`` adds seeded per-message log-normal latency jitter.

    Multi-tenant serving (DESIGN.md §13): ``tenant_mix`` is a named
    workload mix from ``repro.cluster.workload.TENANT_MIXES`` (or an
    explicit tuple of `TenantWorkload`) that splits the device fleet
    into per-tenant groups and compiles their admission contracts into
    a shared `TenantRegistry`; ``tenants`` adds/overrides registry
    entries (`TenantSpec` objects or ``name[:key=value]*`` CLI spec
    strings).  Both empty = the legacy single-tenant stack."""
    if scheduler is not None:
        if policy != "wisp" and policy != scheduler:
            raise ValueError(
                f"pass either policy={policy!r} or the deprecated "
                f"scheduler={scheduler!r}, not both"
            )
        warnings.warn(
            "run_serving(scheduler=...) is deprecated; use policy=...",
            DeprecationWarning, stacklevel=2,
        )
        policy = scheduler
    if q_mode == "none" and method != "greedy":
        # a residual/target verifier with no q statistics would silently
        # fall back to the staging buffers' uniform fill — an accept test
        # of u <= p·V, not the paper's rule.  Only greedy reads no q.
        raise ValueError(
            f"q_mode='none' requires method='greedy' (got {method!r}): "
            "residual/target verification needs dense or compact q"
        )
    tcfg = get_config(target_arch)
    dcfg = get_config(draft_arch or target_arch)
    if reduced:
        tcfg, dcfg = tcfg.reduced(), dcfg.reduced()
    if dcfg.vocab != tcfg.vocab:
        raise ValueError("draft/target vocab mismatch")

    tb = build(tcfg)
    tparams = tb.init(jax.random.PRNGKey(seed))
    if self_draft:
        # self-speculation: the draft IS the target (with greedy drafting
        # and greedy verification every block fully accepts and every
        # speculative continuation commits — the overlap-pipelining upper
        # bound)
        dcfg, dparams = tcfg, tparams
    else:
        dparams = build(dcfg).init(jax.random.PRNGKey(seed + 1))

    if sync and prefill_mode != "zero":
        # the lock-step reference has no clock to charge prefill against;
        # it always opens sessions through the blocking monolithic path
        raise ValueError("--sync supports prefill_mode='zero' only")
    if sync and (fault_schedule is not None or link_timeout is not None
                 or link_jitter):
        # the lock-step loop has no virtual clock to lose messages or arm
        # retry timers against
        raise ValueError("--sync does not support the edge-link fault "
                         "domain (fault_schedule/link_timeout/link_jitter)")
    if isinstance(tenant_mix, str):
        if tenant_mix not in TENANT_MIXES:
            raise ValueError(
                f"unknown tenant mix {tenant_mix!r}; "
                f"known: {sorted(TENANT_MIXES)}"
            )
        tenant_workloads = TENANT_MIXES[tenant_mix]
    else:
        tenant_workloads = tuple(tenant_mix or ())
    if tenant_workloads and sync:
        raise ValueError("--sync is single-tenant only")
    ccfg = ClusterConfig(
        devices=devices,
        rounds=None if churn else rounds,
        horizon=horizon,
        k_max=k_max,
        draft_speeds=tuple(draft_speeds),
        prompt_len=prompt_len,
        max_len=max_len,
        seed=seed,
        speculate=speculate,
        spec_policy=spec_policy,
        link_rtts=tuple(link_rtts),
        dispatch_interval=dispatch_interval,
        prefill_mode=prefill_mode,
        prefill_chunk_tokens=prefill_chunk_tokens,
        think_time_mean=think_time_mean,
        response_len_mean=response_len_mean,
        q_mode=q_mode,
        q_top_c=q_top_c,
        verifiers=verifiers,
        fail_at=tuple(fail_at),
        straggle=tuple(straggle),
        fault_schedule=fault_schedule,
        link_timeout=link_timeout,
        link_backoff=link_backoff,
        link_degrade=link_degrade,
        jitter_sigma=link_jitter,
        heartbeat_interval=heartbeat_interval,
        heartbeat_timeout=heartbeat_timeout,
        hedge_factor=hedge_factor,
        hedge_guard=hedge_guard,
        kv_tier_pages=kv_tier_pages,
        spill_quantize=spill_quantize,
        spill_idle_epochs=spill_idle_epochs,
        tenant_workloads=tenant_workloads,
    )
    fleet = build_fleet(ccfg, tcfg.vocab)
    devices = len(fleet)                 # tenant mixes resize the fleet

    # one registry per run: shared across every verifier so tenant
    # budgets and fair-share accounting are fleet-global
    from repro.tenancy import TenantRegistry, TenantSpec

    if isinstance(tenants, TenantRegistry):
        registry = tenants
    else:
        registry = build_tenant_registry(ccfg)
        for spec in tenants or ():
            if isinstance(spec, str):
                spec = TenantSpec.parse(spec)
            registry.register(spec)

    coeffs = coeffs or analytic_tpu_coeffs(tcfg)
    net = NetworkModel()
    if verifiers > 1:
        if sync:
            raise ValueError("--sync is single-verifier only")
        from repro.fleet import build_verifier_fleet

        router = build_verifier_fleet(
            tcfg, tparams, verifiers, coeffs, max_slots=devices,
            max_len=max_len, method=method, policy=policy,
            sched_cfg=sched_cfg, network=net,
            prefill="chunked" if prefill_mode == "chunked" else "monolithic",
            prefill_chunk_tokens=prefill_chunk_tokens,
            slo_classes=slo_speeds, ttft_slo=ttft_slo,
            heartbeat_timeout=heartbeat_timeout,
            hedge_factor=hedge_factor, hedge_guard=hedge_guard,
            kv_tier_pages=kv_tier_pages, spill_quantize=spill_quantize,
            spill_idle_epochs=spill_idle_epochs,
            tenants=registry,
        )
        engine = next(iter(router.verifiers.values())).engine
        server = router
    else:
        engine = VerificationEngine(tcfg, tparams, max_slots=devices,
                                    max_len=max_len, method=method,
                                    kv_tier_pages=kv_tier_pages,
                                    spill_quantize=spill_quantize,
                                    spill_idle_epochs=spill_idle_epochs)
        server = WISPServer(
            engine, coeffs, policy=policy, network=net,
            slo_classes=slo_speeds, sched_cfg=sched_cfg,
            prefill="chunked" if prefill_mode == "chunked" else "monolithic",
            prefill_chunk_tokens=prefill_chunk_tokens, ttft_slo=ttft_slo,
            tenants=registry,
        )

    edges = [
        EdgeDevice(
            dcfg, dparams, predictor=predictor, k_max=k_max,
            max_len=max_len, seed=seed + 10 + sp.idx,
            draft_speed=sp.draft_speed, greedy=greedy,
            q_mode=q_mode, q_top_c=q_top_c,
            spec_policy=spec_policy,
            spec_cfg={"degrade": True} if link_degrade else None,
        )
        for sp in fleet
    ]

    if sync:
        return _run_lockstep(server, edges, fleet, rounds, net, verbose)

    t_wall0 = time.time()
    if verifiers > 1:
        from repro.fleet import FleetRuntime

        runtime = FleetRuntime(router, edges, fleet, ccfg, vocab=tcfg.vocab)
    else:
        runtime = ClusterRuntime(server, edges, fleet, ccfg, vocab=tcfg.vocab)
    result = runtime.run()
    wall = time.time() - t_wall0
    engines = server.engines if verifiers > 1 else [engine]
    n_batches = sum(e.stats["batches"] for e in engines)
    n_chunks = sum(e.stats["prefill_chunks"] for e in engines)

    m = result.metrics
    stats = [m.per_session.get(sp.idx, WDTStats()) for sp in fleet] \
        if not churn else []
    total = WDTStats()
    for it in m.iterations:
        total.add(it, 0.0)
    if verbose:
        print(f"[serve] mode=event devices={devices} "
              f"{'horizon=%.1fs' % result.horizon if churn else 'rounds=%d' % rounds} "
              f"policy={server.policy} spec_policy={spec_policy} "
              f"speculate={speculate} prefill={prefill_mode}")
        if prefill_mode != "zero" and m.sessions:
            # chunked mode logs TTFT-deadline outcomes per prefill; the
            # monolithic path has no prefill_log, so judge its sessions'
            # measured TTFT against the same per-class budgets
            ttft_viol = (
                sum(r.violated for r in server.prefill_log)
                if server.prefill_log
                else sum(s.ttft > server.ttft_slo[s.slo_class]
                         for s in m.sessions)
            )
            print(f"[serve] ttft: p50={m.ttft_quantile(0.5)*1e3:.1f} ms "
                  f"p99={m.ttft_quantile(0.99)*1e3:.1f} ms "
                  f"prefill_chunks={n_chunks} "
                  f"ttft_violations={ttft_viol}")
        print(f"[serve] drafted={total.drafted} accepted={total.accepted} "
              f"committed={total.committed} acceptance={total.acceptance_rate:.3f}")
        print(f"[serve] measured: goodput={m.goodput(result.horizon):.1f} tok/s "
              f"wdt={m.t_wdt*1e3:.1f} ms waste_frac={m.waste_fraction():.3f} "
              f"mean_queue={m.mean_queue_time()*1e3:.2f} ms")
        s = m.spec
        print(f"[serve] speculation: commits={s.commits} rollbacks={s.rollbacks} "
              f"salvaged={s.salvaged} discarded={s.discarded} "
              f"commit_rate={s.commit_rate:.2f}")
        print(f"[serve] sessions={len(m.sessions)} "
              f"violations={m.violations()} "
              f"deadline_misses={m.deadline_violations()} "
              f"engine batches={n_batches} wall={wall:.1f}s")
        if tenant_workloads:
            weights = {tw.name: tw.weight for tw in tenant_workloads}
            print(f"[serve] tenants: "
                  f"jain_fairness={m.jain_fairness(result.horizon, weights):.3f}")
            for tn, row in m.per_tenant(result.horizon).items():
                print(f"[serve]   {tn}: "
                      f"goodput={row['goodput_tok_s']:.1f} tok/s "
                      f"sessions={row['sessions']} "
                      f"violations={row['session_violations']} "
                      f"rejections={row['rejections']}")
        if kv_tier_pages > 0:
            sp_pages = sum(e.stats["pages_spilled"] for e in engines)
            pi_pages = sum(e.stats["pages_paged_in"] for e in engines)
            sp_mb = sum(e.stats["spill_bytes"] for e in engines) / 2**20
            pi_mb = sum(e.stats["pagein_bytes"] for e in engines) / 2**20
            print(f"[serve] kv-tier: host_pages={kv_tier_pages} "
                  f"quantize={spill_quantize} spilled={sp_pages} "
                  f"({sp_mb:.2f} MiB) paged_in={pi_pages} "
                  f"({pi_mb:.2f} MiB)")
        if (fault_schedule is not None or link_timeout is not None
                or m.chaos.retries or m.chaos.uplink_drops
                or m.chaos.downlink_drops):
            c = m.chaos
            print(f"[serve] chaos: retries={c.retries} timeouts={c.timeouts} "
                  f"up_drop={c.uplink_drops} down_drop={c.downlink_drops} "
                  f"dup_verdicts_dropped={c.dup_verdicts_dropped} "
                  f"replays={c.verdicts_replayed} "
                  f"link_down={c.link_down_events} "
                  f"link_up={c.link_up_events} "
                  f"degraded_rounds={c.degraded_rounds}")
        if verifiers > 1:
            fs = server.stats
            print(f"[serve] fleet: verifiers={verifiers} "
                  f"downs={fs['verifier_downs']} rejoins={fs['rejoins']} "
                  f"migrations={fs['migrations']} reopens={fs['reopens']} "
                  f"redispatches={fs['redispatches']} "
                  f"lost_verdicts={fs['lost_verdicts']}")
        for i, dev in enumerate(edges[:4]):
            if dev.session is not None:
                print(f"[serve] dev{i} response: {dev.response_tokens[:12]}")
    return {"stats": stats, "total": total, "edges": edges, "server": server,
            "metrics": m, "result": result}


def _run_lockstep(server, edges, fleet, rounds, net, verbose):
    """The original synchronous round loop (reference / ``--sync``): all
    devices draft, the pool drains through dispatch epochs, verdicts apply,
    repeat.  No drafting/verification overlap exists, so WDT here is the
    analytic accounting of `core/wdt.py`, not a measurement.

    This driver deliberately sticks to the LEGACY channels — the
    ``open_session`` handle's synchronous ``first_token`` and the
    ``step()`` verdict return list — so the event-driven runtime's
    stream-equivalence guarantee is checked against a consumer of the
    deprecation shims (tests/test_policies.py)."""
    stats = []
    for sp, dev in zip(fleet, edges):
        # synchronous driver: every device must be admitted up front, so
        # fail loudly on capacity exhaustion instead of queueing
        handle = server.open_session(sp.idx, sp.prompt,
                                     slo_class=sp.slo_class,
                                     draft_speed=sp.draft_speed,
                                     queue_on_full=False)
        dev.start_session(sp.idx, sp.prompt, handle.first_token)
        stats.append(WDTStats())

    now = 0.0
    t_wall0 = time.time()
    for r in range(rounds):
        # all devices draft and submit (synchronous round model on CPU)
        results = {}
        for i, dev in enumerate(edges):
            res = dev.draft_round()
            t_net = net.round_trip(res.n_sent, res.q_payload())
            server.submit(i, res.tokens, res.q_logits,
                          q_compact=res.q_compact, now=now,
                          t_draft=res.draft_time, t_network=t_net)
            results[i] = (res, t_net)
        # dispatch epochs until the pool drains
        while server.queue_depth:
            verdicts = server.step(now)
            if not verdicts:
                now += 0.005   # idle epoch: advance time to unblock criticals
                continue
            server.pop_events()   # discard the mirrored event stream: this
            # driver reads the legacy channels, and an undrained event
            # buffer would otherwise grow per round in long runs
            for v in verdicts:
                res, t_net = results[v.session_id]
                edges[v.session_id].apply_verdict(
                    v.accept_len, v.token, res.tokens
                )
                edges[v.session_id].observe_verdict(
                    v.accept_len, res.k_used, rtt=t_net,
                    queue_depth=getattr(v, "queue_depth", None),
                    features=res.features,
                )
                stats[v.session_id].add(
                    IterationLog(
                        session_id=v.session_id,
                        round_index=r,
                        n_drafted=res.n_drafted,
                        n_sent=res.n_sent,
                        n_accepted=v.accept_len,
                        n_committed=v.emitted,
                        t_draft=res.draft_time,
                        t_network=t_net,
                        t_queue=v.t_queue,
                        t_verify=v.t_verify,
                        violated=v.violated,
                        k_used=res.k_used,
                    ),
                    tau_d=1.0 / edges[v.session_id].controller.draft_speed,
                )
            now += 0.01
    wall = time.time() - t_wall0

    total = WDTStats()
    for s in stats:
        total.iterations += s.iterations
        total.drafted += s.drafted
        total.sent += s.sent
        total.accepted += s.accepted
        total.committed += s.committed
        total.wasted += s.wasted
        total.violations += s.violations
    if verbose:
        engine = server.engine
        print(f"[serve] mode=sync devices={len(edges)} rounds={rounds} "
              f"policy={server.policy}")
        print(f"[serve] drafted={total.drafted} accepted={total.accepted} "
              f"committed={total.committed} waste_frac={total.waste_fraction:.3f} "
              f"acceptance={total.acceptance_rate:.3f}")
        print(f"[serve] engine batches={engine.stats['batches']} wall={wall:.1f}s")
        for i, dev in enumerate(edges[:4]):
            print(f"[serve] dev{i} response: {dev.response_tokens[:12]}")
    return {"stats": stats, "total": total, "edges": edges, "server": server}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--target", default="qwen2-7b")
    ap.add_argument("--draft", default=None)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--devices", type=int, default=4)
    ap.add_argument("--rounds", type=int, default=8)
    ap.add_argument("--k-max", type=int, default=6)
    ap.add_argument("--policy", default="wisp",
                    choices=(*available_policies(), "slo"),
                    help="batch-selection policy from the scheduling "
                         "registry ('slo' is a legacy alias of 'wisp')")
    ap.add_argument("--scheduler", dest="policy", help=argparse.SUPPRESS)
    ap.add_argument("--spec-policy", default="static",
                    choices=tuple(available_spec_policies()),
                    help="per-session draft-length policy from the "
                         "speculation-controller registry (DESIGN.md §11): "
                         "static (K = k_max every block) or adaptive "
                         "(per-block K from acceptance/RTT/verifier load)")
    ap.add_argument("--predictor-path", default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--sync", action="store_true",
                    help="lock-step reference driver (no overlap)")
    ap.add_argument("--no-speculate", action="store_true",
                    help="event-driven but without speculative continuation")
    ap.add_argument("--churn", action="store_true",
                    help="session churn (Poisson think times) until --horizon")
    ap.add_argument("--horizon", type=float, default=20.0,
                    help="virtual-seconds horizon for --churn")
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--prefill", choices=("zero", "monolithic", "chunked"),
                    default="zero",
                    help="how prompt prefill is charged on the virtual "
                         "clock (DESIGN.md §8)")
    ap.add_argument("--prefill-chunk", type=int, default=32,
                    help="prompt tokens per schedulable prefill chunk")
    ap.add_argument("--q-mode", choices=("dense", "compact", "none"),
                    default="dense",
                    help="draft q payload: dense (K,V) logits, compact "
                         "top-C table (O(K*C) uplink), or none (greedy)")
    ap.add_argument("--q-top-c", type=int, default=64,
                    help="top-C table width for --q-mode compact")
    ap.add_argument("--verifiers", type=int, default=1,
                    help="verifier replicas behind the prefix-locality "
                         "router (repro.fleet); 1 = single-server runtime")
    ap.add_argument("--fail-at", action="append", default=[],
                    metavar="IDX:T0[:T1]",
                    help="DEPRECATED (compiles onto --fault-schedule): kill "
                         "verifier IDX at virtual time T0 (recover at T1 if "
                         "given); repeatable")
    ap.add_argument("--straggle", action="append", default=[],
                    metavar="IDX:T0:T1:FACTOR",
                    help="DEPRECATED (compiles onto --fault-schedule): slow "
                         "verifier IDX's epochs by FACTOR in [T0,T1); "
                         "repeatable")
    ap.add_argument("--fault-schedule", default=None, metavar="SPEC",
                    help="seeded chaos plan (DESIGN.md §14): a preset "
                         "('lossy', 'flap', 'storm') or a DSL string, e.g. "
                         "'drop=0.1,dup=0.05,linkdown@0.25+0.5,seed=7,"
                         "kill=0@0.5'")
    ap.add_argument("--link-timeout", type=float, default=None,
                    metavar="SECONDS",
                    help="edge per-round timeout before an idempotent "
                         "re-submission (exponential backoff + jitter); "
                         "unset = no retries")
    ap.add_argument("--link-degrade", action="store_true",
                    help="let link health degrade speculation depth "
                         "(K shrinks under flap, K=1 while the link is "
                         "down; changes committed streams like adaptive-K)")
    ap.add_argument("--link-jitter", type=float, default=0.0,
                    metavar="SIGMA",
                    help="per-message log-normal latency jitter sigma on "
                         "the modelled network (seeded; 0 = fixed RTT)")
    ap.add_argument("--kv-tier", type=int, default=0, metavar="PAGES",
                    help="host-DRAM KV spill pool size in pages under each "
                         "verifier's device page pool (DESIGN.md §12); "
                         "0 = no tier")
    ap.add_argument("--spill-quantize", action="store_true",
                    help="int8-quantize KV pages on spill (per-page scales; "
                         "stored only when the dequantization round-trips "
                         "bit-exactly, raw fallback otherwise)")
    ap.add_argument("--spill-idle", type=int, default=2,
                    metavar="EPOCHS",
                    help="engine dispatches a session must sit idle before "
                         "its pages become spill candidates")
    ap.add_argument("--tenant-mix", default=None,
                    choices=tuple(sorted(TENANT_MIXES)),
                    help="named multi-tenant workload mix (DESIGN.md §13): "
                         "splits the fleet into per-tenant device groups "
                         "and applies their admission contracts")
    ap.add_argument("--tenants", action="append", default=[],
                    metavar="NAME[:KEY=VAL]*",
                    help="add/override a tenant registry entry, e.g. "
                         "flood:weight=1:rate=600:burst=128:queued=2; "
                         "keys: weight, slo, rate, burst, inflight, conc, "
                         "queued; repeatable")
    args = ap.parse_args()

    def _parse_fail(spec: str) -> tuple:
        parts = spec.split(":")
        if len(parts) not in (2, 3):
            raise SystemExit(f"--fail-at wants IDX:T0[:T1], got {spec!r}")
        return (int(parts[0]), float(parts[1]),
                float(parts[2]) if len(parts) == 3 else None)

    def _parse_straggle(spec: str) -> tuple:
        parts = spec.split(":")
        if len(parts) != 4:
            raise SystemExit(f"--straggle wants IDX:T0:T1:FACTOR, got {spec!r}")
        return (int(parts[0]), float(parts[1]), float(parts[2]),
                float(parts[3]))

    if args.fail_at or args.straggle:
        warnings.warn(
            "--fail-at / --straggle are deprecated; use --fault-schedule "
            "(e.g. 'kill=0@0.5' / 'straggle=1@0.05+0.95*400') — the legacy "
            "flags compile onto the schedule for now",
            DeprecationWarning, stacklevel=2,
        )
    pred = RejectionPredictor.load(args.predictor_path) if args.predictor_path else None
    run_serving(
        args.target, args.draft, devices=args.devices, rounds=args.rounds,
        k_max=args.k_max, policy=args.policy, predictor=pred,
        spec_policy=args.spec_policy,
        seed=args.seed, sync=args.sync, speculate=not args.no_speculate,
        churn=args.churn, horizon=args.horizon if args.churn else None,
        prompt_len=args.prompt_len, prefill_mode=args.prefill,
        prefill_chunk_tokens=args.prefill_chunk,
        q_mode=args.q_mode, q_top_c=args.q_top_c,
        verifiers=args.verifiers,
        fail_at=tuple(_parse_fail(s) for s in args.fail_at),
        straggle=tuple(_parse_straggle(s) for s in args.straggle),
        fault_schedule=args.fault_schedule,
        link_timeout=args.link_timeout,
        link_degrade=args.link_degrade,
        link_jitter=args.link_jitter,
        kv_tier_pages=args.kv_tier,
        spill_quantize=args.spill_quantize,
        spill_idle_epochs=args.spill_idle,
        tenant_mix=args.tenant_mix,
        tenants=tuple(args.tenants),
    )


if __name__ == "__main__":
    main()
