"""Training driver.

Runs a real training loop for any registered architecture on whatever mesh
fits the available devices (production meshes come from ``mesh.py``; on the
CPU container use ``--reduced`` + the default 1x1 mesh).  Features:

  * deterministic sharded data pipeline (resumable by step),
  * checkpoint/restart (atomic sharded checkpoints, async save),
  * elastic restore — a run checkpointed on one mesh restores onto another
    (``--data/--model`` may differ across restarts),
  * loss/throughput logging with MODEL_FLOPS-based MFU estimate.

Example (CPU):
  python -m repro.launch.train --arch qwen2-7b --reduced --steps 20 \\
      --batch 8 --seq 128 --ckpt-dir /tmp/ck --ckpt-every 10
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.sharding import TRAIN_RULES, logical_to_spec
from repro.configs import get_config
from repro.data.pipeline import ShardedLoader
from repro.data.synthetic import SyntheticLMConfig
from repro.launch.mesh import make_test_mesh
from repro.models import batch_axes, build
from repro.roofline.model_flops import model_flops
from repro.runtime.checkpoint import CheckpointManager, latest_step
from repro.train.optimizer import OptConfig
from repro.train.train_step import make_train_step


def extras_for(cfg, batch, dtype=jnp.bfloat16):
    """Stub modality frontends (vlm patches / audio frames)."""
    if cfg.family == "vlm":
        return lambda step: {
            "image_embeds": jnp.zeros(
                (batch, cfg.num_image_tokens, cfg.d_model), dtype
            )
        }
    if cfg.family == "audio":
        return lambda step: {
            "frames": jnp.zeros((batch, cfg.encoder_frames, cfg.d_model), dtype)
        }
    return None


def train(
    arch: str,
    *,
    steps: int = 50,
    batch: int = 8,
    seq: int = 128,
    reduced: bool = True,
    mesh=None,
    data_axis: int = 1,
    model_axis: int = 1,
    opt: str = "adamw",
    lr: float = 3e-4,
    ckpt_dir: str | None = None,
    ckpt_every: int = 0,
    log_every: int = 10,
    seed: int = 0,
    remat: bool = False,
):
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    mesh = mesh or make_test_mesh(data_axis, model_axis)
    bundle = build(cfg)

    step_fn, info = make_train_step(
        cfg, mesh, opt_cfg=OptConfig(name=opt, lr=lr), remat=remat
    )

    # ---- init or restore ---------------------------------------------------
    mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None
    start_step = 0
    if mgr and latest_step(ckpt_dir) is not None:
        state, meta = mgr.restore_latest(
            shardings={"params": info["params"], "opt": info["opt"]}
        )
        params, opt_state = state["params"], state["opt"]
        start_step = int(meta["step"])
        print(f"[train] restored step {start_step} from {ckpt_dir}", flush=True)
    else:
        with mesh:
            params = jax.jit(
                lambda k: bundle.init(k), out_shardings=info["params"]
            )(jax.random.PRNGKey(seed))
            opt_state = jax.jit(
                info["init_opt"], out_shardings=info["opt"]
            )(params)

    # ---- data ----------------------------------------------------------------
    tok_sharding = jax.sharding.NamedSharding(
        mesh,
        logical_to_spec(
            batch_axes(cfg, with_targets=True)["tokens"], (batch, seq), mesh,
            TRAIN_RULES,
        ),
    )
    loader = ShardedLoader(
        SyntheticLMConfig(vocab=cfg.vocab, seq_len=seq, seed=seed),
        batch,
        tok_sharding,
        start_step=start_step,
        extras_fn=extras_for(cfg, batch),
    )

    # ---- loop ----------------------------------------------------------------
    mf_per_step = model_flops(cfg, batch * seq, training=True)
    losses = []
    t0 = time.time()
    for step in range(start_step, steps):
        batch_arrays = next(loader)
        params, opt_state, metrics = step_fn(params, opt_state, batch_arrays)
        if log_every and (step % log_every == 0 or step == steps - 1):
            loss = float(metrics["loss"])
            dt = time.time() - t0
            tput = (step - start_step + 1) * batch * seq / max(dt, 1e-9)
            print(
                f"[train] step {step:5d} loss {loss:8.4f} "
                f"gnorm {float(metrics['grad_norm']):8.3f} "
                f"tok/s {tput:10.1f} flops/s {mf_per_step * (step - start_step + 1) / max(dt, 1e-9):.3e}",
                flush=True,
            )
            losses.append(loss)
        if mgr and ckpt_every and (step + 1) % ckpt_every == 0:
            mgr.save(
                step + 1,
                {"params": params, "opt": opt_state},
                meta={"step": step + 1, "arch": arch},
                blocking=False,
            )
    if mgr:
        mgr.wait()
        mgr.save(steps, {"params": params, "opt": opt_state},
                 meta={"step": steps, "arch": arch})
    return {"losses": losses, "params": params, "opt_state": opt_state,
            "final_loss": losses[-1] if losses else None}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--data", type=int, default=1)
    ap.add_argument("--model", type=int, default=1)
    ap.add_argument("--opt", default="adamw")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--remat", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    out = train(
        args.arch,
        steps=args.steps,
        batch=args.batch,
        seq=args.seq,
        reduced=args.reduced,
        data_axis=args.data,
        model_axis=args.model,
        opt=args.opt,
        lr=args.lr,
        ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every,
        remat=args.remat,
        seed=args.seed,
    )
    print(f"[train] done, final loss {out['final_loss']}")


if __name__ == "__main__":
    main()
