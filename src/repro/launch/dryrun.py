import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first two lines: jax locks the device count on first init,
#   and the production-mesh dry-run needs 512 placeholder host devices.
#   (Set here only — smoke tests and benches must see 1 device.)

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each runnable cell this lowers the corresponding jitted step
(train_step / prefill_step / serve_step) against ShapeDtypeStruct inputs
(no allocation), compiles it for the production mesh, and records

  * ``compiled.memory_analysis()``  — proves the cell fits per-device HBM,
  * ``compiled.cost_analysis()``    — FLOPs / bytes for the roofline,
  * parsed collective traffic      — the third roofline term,

into one JSON artifact per cell under ``artifacts/dryrun/``.

Usage:
  python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k
  python -m repro.launch.dryrun --all                     # single-pod, all cells
  python -m repro.launch.dryrun --all --multipod          # 2x16x16 mesh
  python -m repro.launch.dryrun --all --mesh both --skip-existing
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.common.sharding import (
    SERVE_RULES,
    SERVE_RULES_REPLICATED,
    TRAIN_RULES,
    ShardCtx,
    logical_to_spec,
    make_param_shardings,
)

#: §Perf variants — named sharding/step configurations compared by the
#: hillclimb.  "baseline" is the paper-faithful layout.
SERVE_VARIANTS = {
    "baseline": dict(rules=SERVE_RULES),
    "replicated": dict(rules=SERVE_RULES_REPLICATED),
}
from repro.configs import ASSIGNED, SHAPES, cell_status, get_config
from repro.launch.mesh import make_production_mesh, mesh_devices
from repro.roofline.analysis import analyze
from repro.roofline.model_flops import (
    attention_flops,
    decode_attention_flops,
    model_flops,
    uncounted_sequential_flops,
)

#: archs whose optimizer state would not fit HBM under AdamW (f32 m+v);
#: they train with Adafactor (factored second moments) — see DESIGN.md.
ADAFACTOR_ABOVE_PARAMS = 20e9


# ---------------------------------------------------------------------------
# step builders — each returns (jitted_fn, input ShapeDtypeStructs w/ shardings)
# ---------------------------------------------------------------------------


def _specs_with_shardings(shapes_tree, shardings_tree):
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        shapes_tree,
        shardings_tree,
    )


def build_train_cell(cfg, shape, mesh, *, micro_batches=1):
    from repro.models import batch_axes, batch_specs, build
    from repro.train.optimizer import OptConfig
    from repro.train.train_step import make_train_step

    opt_name = "adafactor" if cfg.param_count() > ADAFACTOR_ABOVE_PARAMS else "adamw"
    step, info = make_train_step(
        cfg, mesh, opt_cfg=OptConfig(name=opt_name),
        micro_batches=micro_batches,
    )
    p_specs = _specs_with_shardings(info["param_shapes"], info["params"])
    o_shapes = jax.eval_shape(info["init_opt"], info["param_shapes"])
    o_specs = _specs_with_shardings(o_shapes, info["opt"])
    b_axes = batch_axes(cfg, with_targets=True)
    bs = batch_specs(cfg, shape.global_batch, shape.seq_len, with_targets=True)
    b_specs = {
        k: jax.ShapeDtypeStruct(
            bs[k].shape,
            bs[k].dtype,
            sharding=jax.sharding.NamedSharding(
                mesh, logical_to_spec(b_axes[k], bs[k].shape, mesh, TRAIN_RULES)
            ),
        )
        for k in bs
    }
    meta = {"optimizer": opt_name}
    return step, (p_specs, o_specs, b_specs), meta


def _serve_param_specs(cfg, mesh, rules):
    from repro.models import build
    from repro.train.train_step import make_param_shardings, param_shapes

    bundle = build(cfg)
    shapes = param_shapes(cfg)
    sh = make_param_shardings(bundle.param_axes(), shapes, mesh, rules)
    return _specs_with_shardings(shapes, sh)


def _cache_specs_sharded(cfg, mesh, B, max_len, rules):
    from repro.models import build
    from repro.train.train_step import cache_shardings

    bundle = build(cfg)
    shapes = jax.eval_shape(lambda: bundle.init_cache(B, max_len))
    shardings = cache_shardings(cfg, mesh, B, max_len, rules=rules)
    return _specs_with_shardings(shapes, shardings)


def _batch_specs_sharded(cfg, mesh, B, S, rules):
    from repro.models import batch_axes, batch_specs

    axes = batch_axes(cfg, with_targets=False)
    bs = batch_specs(cfg, B, S, with_targets=False)
    return {
        k: jax.ShapeDtypeStruct(
            bs[k].shape,
            bs[k].dtype,
            sharding=jax.sharding.NamedSharding(
                mesh, logical_to_spec(axes[k], bs[k].shape, mesh, rules)
            ),
        )
        for k in bs
    }


def build_prefill_cell(cfg, shape, mesh, *, rules=SERVE_RULES):
    from repro.models import build

    bundle = build(cfg)
    ctx = ShardCtx(mesh, rules)
    B, S = shape.global_batch, shape.seq_len

    def prefill_step(params, batch, cache):
        return bundle.prefill(params, batch, cache, ctx=ctx, last_only=True)

    p_specs = _serve_param_specs(cfg, mesh, rules)
    b_specs = _batch_specs_sharded(cfg, mesh, B, S, rules)
    c_specs = _cache_specs_sharded(cfg, mesh, B, S, rules)
    step = jax.jit(prefill_step, donate_argnums=(2,))
    return step, (p_specs, b_specs, c_specs), {}


def build_decode_cell(cfg, shape, mesh, *, k_draft: int = 0,
                      rules=SERVE_RULES):
    """serve_step: T new tokens (T=1 decode, T=k+1 speculative verify)
    against a KV cache of seq_len."""
    from repro.models import build

    bundle = build(cfg)
    ctx = ShardCtx(mesh, rules)
    B, S = shape.global_batch, shape.seq_len
    T = 1 + k_draft

    def serve_step(params, tokens, cache, pos):
        return bundle.decode(params, tokens, cache, pos, ctx=ctx)

    p_specs = _serve_param_specs(cfg, mesh, rules)
    tok_spec = jax.ShapeDtypeStruct(
        (B, T),
        jnp.int32,
        sharding=jax.sharding.NamedSharding(
            mesh,
            logical_to_spec(("act_batch", None), (B, T), mesh, rules),
        ),
    )
    c_specs = _cache_specs_sharded(cfg, mesh, B, S, rules)
    pos_spec = jax.ShapeDtypeStruct((), jnp.int32)
    step = jax.jit(serve_step, donate_argnums=(2,))
    return step, (p_specs, tok_spec, c_specs, pos_spec), {"t_new": T}


def model_flops_for_cell(cfg, shape, *, k_draft: int = 0) -> float:
    """MODEL_FLOPS = 6·N_active·D (train) / 2·N_active·D (+attention) for
    serving cells — the 'useful compute' yardstick of §Roofline."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        return model_flops(cfg, B * S, training=True) + 3 * attention_flops(
            cfg, S, B
        )
    if shape.kind == "prefill":
        return model_flops(cfg, B * S, training=False) + attention_flops(cfg, S, B)
    T = 1 + k_draft
    return model_flops(cfg, B * T, training=False) + decode_attention_flops(
        cfg, S, B, T
    )


# ---------------------------------------------------------------------------
# cell runner
# ---------------------------------------------------------------------------


def structural_unit(cfg) -> int:
    """Smallest depth preserving the arch's layer-group structure."""
    unit = 1
    if cfg.local_global_alternate:
        unit = max(unit, 2)
    if cfg.cross_attn_every:
        unit = max(unit, cfg.cross_attn_every)
    if cfg.ssm is not None:
        if cfg.ssm.slstm_every:
            unit = max(unit, cfg.ssm.slstm_every)
        if cfg.ssm.attn_every:
            unit = max(unit, cfg.ssm.attn_every)
    return unit


def _compile_cell(cfg, shape, mesh, *, kind, k_draft, variant, micro_batches,
                  unroll):
    """Lower+compile one configuration."""
    from repro.common import loops

    t0 = time.time()
    v = SERVE_VARIANTS.get(variant, SERVE_VARIANTS["baseline"])
    builders = {
        "train": lambda c, s, m: build_train_cell(
            c, s, m, micro_batches=micro_batches
        ),
        "prefill": lambda c, s, m: build_prefill_cell(c, s, m, **v),
        "decode": lambda c, s, m: build_decode_cell(
            c, s, m, k_draft=k_draft, **v
        ),
    }
    step, specs, meta = builders[kind](cfg, shape, mesh)
    with mesh, loops.cost_unroll(unroll):
        lowered = step.lower(*specs)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        cost = dict(compiled.cost_analysis())
        memstats = compiled.memory_analysis()
        hlo = compiled.as_text()
    return cost, memstats, hlo, meta, (t_lower, t_compile)


def _cost_terms(cost, hlo):
    from repro.roofline.hlo_parse import collective_summary

    coll = collective_summary(hlo)
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll_dev": coll["bytes_per_device"],
        "coll_global": coll["bytes_global"],
        "per_kind": coll["per_kind"],
    }


def run_cell(arch, shape_name, *, multi_pod=False, k_draft=0, verbose=True,
             unroll=True, variant="baseline", micro_batches=1):
    """Cost accounting: XLA's cost_analysis visits while-loop bodies ONCE,
    so scanned layer stacks undercount by ~n_layers.  Full unrolling is
    exact but compiles too slowly for 100-layer stacks, so we exploit that
    every stack is layer-homogeneous: cost(L) = intercept + slope*L.  Two
    unrolled compiles at L=unit and L=2*unit identify the line exactly; the
    roofline evaluates it at the full depth.  Memory fit (and the compile
    proof) come from the full-depth scanned compile."""
    import dataclasses as dc

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = cell_status(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "status": "skipped", "why": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    chips = mesh_devices(mesh)

    # --- full-depth scanned compile: fit proof + memory analysis ---------
    cost_full, memstats, hlo_full, meta, (t_lower, t_compile) = _compile_cell(
        cfg, shape, mesh, kind=shape.kind, k_draft=k_draft, variant=variant,
        micro_batches=micro_batches, unroll=False,
    )
    meta["variant"] = variant
    if micro_batches > 1:
        meta["micro_batches"] = micro_batches

    coll_override = None
    if unroll:
        unit = structural_unit(cfg)
        fits = []
        for L in (unit, 2 * unit):
            cfg_L = dc.replace(cfg, n_layers=L, name=f"{cfg.name}@L{L}")
            c, _, h, _, _ = _compile_cell(
                cfg_L, shape, mesh, kind=shape.kind, k_draft=k_draft,
                variant=variant, micro_batches=micro_batches, unroll=True,
            )
            fits.append(_cost_terms(c, h))
        L1, L2, Lf = unit, 2 * unit, cfg.n_layers
        lin = lambda v1, v2: v1 + (v2 - v1) * (Lf - L1) / (L2 - L1)
        cost = {
            "flops": lin(fits[0]["flops"], fits[1]["flops"]),
            "bytes accessed": lin(fits[0]["bytes"], fits[1]["bytes"]),
        }
        kinds = sorted(set(fits[0]["per_kind"]) | set(fits[1]["per_kind"]))
        zero = {"count": 0, "bytes_per_device": 0.0, "bytes_global": 0.0}
        per_kind = {
            k: {
                f: lin(fits[0]["per_kind"].get(k, zero)[f],
                       fits[1]["per_kind"].get(k, zero)[f])
                for f in zero
            }
            for k in kinds
        }
        coll_override = {
            "per_kind": per_kind,
            "bytes_per_device": lin(fits[0]["coll_dev"], fits[1]["coll_dev"]),
            "bytes_global": lin(fits[0]["coll_global"], fits[1]["coll_global"]),
        }
        # per-token recurrence loops stay rolled even in unroll mode
        # (trip > UNROLL_LIMIT): analytic FLOPs shortfall (grad ~2x fwd)
        t_new = shape.seq_len if shape.kind in ("train", "prefill") else 1
        corr = uncounted_sequential_flops(cfg, t_new, shape.global_batch)
        if shape.kind == "train":
            corr *= 3.0
        cost["flops"] += corr / chips
        cost_mode = f"unroll-extrapolated(L={L1},{L2}->{Lf})"
        # SSD chunk scans beyond UNROLL_LIMIT trips also stay rolled (the
        # 32k-prefill ssm/hybrid cells): their bodies dominate the layer,
        # so scale the measured terms by the trip count (slight upper
        # bound — out-of-loop work is scaled along).
        if cfg.ssm is not None and shape.kind in ("train", "prefill"):
            from repro.common.loops import UNROLL_LIMIT

            trips = shape.seq_len // max(cfg.ssm.chunk, 1)
            if trips > UNROLL_LIMIT:
                cost["flops"] *= trips
                cost["bytes accessed"] *= trips
                for k in coll_override["per_kind"].values():
                    for f in k:
                        k[f] *= trips
                coll_override["bytes_per_device"] *= trips
                coll_override["bytes_global"] *= trips
                cost_mode += f"+chunk-scaled(x{trips})"
    else:
        cost = cost_full
        cost_mode = "scanned(loop bodies counted once)"

    mf = model_flops_for_cell(cfg, shape, k_draft=k_draft)
    roof = analyze(
        arch=arch,
        shape=shape_name,
        mesh_name=mesh_name,
        chips=chips,
        cost=cost,
        hlo_text=hlo_full,
        memory_stats=memstats,
        model_flops=mf,
        collectives_override=coll_override,
    )
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "chips": chips,
        "status": "ok",
        "kind": shape.kind,
        "cost_mode": cost_mode,
        "t_lower_s": round(t_lower, 2),
        "t_compile_s": round(t_compile, 2),
        "hlo_instructions": hlo_full.count("\n"),
        **meta,
        "roofline": roof.to_dict(),
    }
    if verbose:
        mem_gb = roof.memory_per_device
        print(
            f"[dryrun] {arch:22s} {shape_name:12s} {mesh_name:11s} OK "
            f"compile={t_compile:6.1f}s "
            f"args={mem_gb['args_bytes']/2**30:7.2f}GiB "
            f"temp={mem_gb['temp_bytes']/2**30:7.2f}GiB "
            f"dom={roof.dominant:10s} "
            f"tc={roof.t_compute*1e3:8.2f}ms tm={roof.t_memory*1e3:8.2f}ms "
            f"tcoll={roof.t_collective*1e3:8.2f}ms",
            flush=True,
        )
    return rec


def artifact_path(out_dir, arch, shape_name, mesh_name, variant="baseline"):
    suffix = "" if variant == "baseline" else f"__{variant}"
    return os.path.join(
        out_dir, f"{arch}__{shape_name}__{mesh_name}{suffix}.json"
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one architecture (default: all)")
    ap.add_argument("--shape", default=None, help="one shape (default: all)")
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--mesh", choices=("pod", "multipod", "both"), default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--k-draft", type=int, default=0,
                    help="speculative draft length for decode serve_step (T=k+1)")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--no-unroll", action="store_true",
                    help="keep lax.scan stacks (fast compile, cost_analysis "
                         "undercounts loop bodies)")
    ap.add_argument("--variant", default="baseline",
                    choices=sorted(SERVE_VARIANTS))
    ap.add_argument("--micro-batches", type=int, default=1)
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(ASSIGNED)
    shapes = [args.shape] if args.shape else list(SHAPES)
    if args.mesh == "both":
        meshes = [False, True]
    elif args.mesh == "multipod" or args.multipod:
        meshes = [True]
    else:
        meshes = [False]

    os.makedirs(args.out, exist_ok=True)
    n_ok = n_skip = n_fail = 0
    for multi in meshes:
        mesh_name = "pod2x16x16" if multi else "pod16x16"
        for arch in archs:
            for shape_name in shapes:
                path = artifact_path(args.out, arch, shape_name, mesh_name,
                                     args.variant)
                if args.skip_existing and os.path.exists(path):
                    with open(path) as f:
                        prev = json.load(f)
                    if prev.get("status") in ("ok", "skipped"):
                        n_ok += prev["status"] == "ok"
                        n_skip += prev["status"] == "skipped"
                        continue
                try:
                    rec = run_cell(
                        arch, shape_name, multi_pod=multi,
                        k_draft=args.k_draft, unroll=not args.no_unroll,
                        variant=args.variant,
                        micro_batches=args.micro_batches,
                    )
                except Exception as e:  # record the failure, keep going
                    rec = {
                        "arch": arch,
                        "shape": shape_name,
                        "mesh": mesh_name,
                        "status": "failed",
                        "error": repr(e),
                        "traceback": traceback.format_exc(),
                    }
                    print(f"[dryrun] {arch} {shape_name} {mesh_name} FAILED: {e!r}",
                          flush=True)
                if rec["status"] == "ok":
                    n_ok += 1
                elif rec["status"] == "skipped":
                    n_skip += 1
                    print(f"[dryrun] {arch:22s} {shape_name:12s} {mesh_name:11s} "
                          f"SKIP ({rec['why']})", flush=True)
                else:
                    n_fail += 1
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
    print(f"[dryrun] done: {n_ok} ok, {n_skip} skipped, {n_fail} failed", flush=True)
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
