"""Production meshes.

Functions (not module constants) so importing never touches device state.

Single pod:  (data=16, model=16)            = 256 chips (TPU v5e pod)
Multi-pod:   (pod=2, data=16, model=16)     = 512 chips across DCN

The `pod` axis is pure data parallelism (gradient all-reduce crosses the
inter-pod link once per step); `data` is FSDP within a pod; `model` is
tensor parallel within an ICI-connected slice.
"""
from __future__ import annotations

import jax


def _mesh_kwargs(n):
    # AxisType landed after jax 0.4; older runtimes default to Auto anyway
    at = getattr(jax.sharding, "AxisType", None)
    return {} if at is None else {"axis_types": (at.Auto,) * n}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_mesh_kwargs(len(axes)))


def make_test_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over available devices (unit tests / CPU)."""
    return jax.make_mesh((data, model), ("data", "model"), **_mesh_kwargs(2))


def mesh_devices(mesh) -> int:
    import math

    return math.prod(mesh.shape.values())
