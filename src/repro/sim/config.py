"""Simulation configuration + hardware profiles."""
from __future__ import annotations

import dataclasses

from repro.core.estimator import EstimatorCoeffs
from repro.serving.transport import NetworkModel

#: Paper App. C Table 12 — A100 80GB + Qwen3-32B (vLLM, prefix cache).
A100_QWEN32B = EstimatorCoeffs(
    a=3.314e-5, b_compute=3.450e-8, b_read=4.620e-6, c=1.486e-2
)

#: token-speed SLO classes, tokens/s (paper §5.1).  NOTE the paper's two
#: tables disagree on class numbering (Table 1: class1=8 tok/s tightest
#: first; Table 2 capacities fall with class index, implying class1=loosest)
#: — we key everything by the tok/s value and only label classes for print.
SLO_SPEEDS = (2.0, 4.0, 6.0, 8.0)


@dataclasses.dataclass(frozen=True)
class DevicePopulation:
    """Heterogeneous edge fleet: draft speeds (tokens/s) cycled over devices
    (paper: Qwen3-0.6B..8B ladder on assorted hardware)."""

    draft_speeds: tuple = (30.0, 50.0, 80.0)
    #: per-token acceptance probability.  Paper Table 5's "Predictor: OFF"
    #: numbers (0.42/0.47/0.53) are *block* acceptance fractions E[L]/K of a
    #: fixed K=8 window; with iid per-token acceptance and stop-at-first-
    #: rejection, E[L]/K = a(1-a^K)/(K(1-a)) — inverting gives the per-token
    #: probabilities below (a = 0.80/0.83/0.855 for the 1.7B/4B/8B drafts).
    base_acceptance: tuple = (0.80, 0.83, 0.855)

    def device(self, i: int) -> tuple[float, float]:
        j = i % len(self.draft_speeds)
        return self.draft_speeds[j], self.base_acceptance[j]


@dataclasses.dataclass
class SimConfig:
    n_devices: int = 16
    sim_time: float = 120.0          # simulated seconds
    warmup: float = 10.0             # stats excluded before this
    seed: int = 0

    # SLO mix: device i gets slo_speeds[i % len] unless homogeneous_slo set
    slo_speeds: tuple = SLO_SPEEDS
    homogeneous_slo: float | None = None

    # drafting
    k_max: int = 8
    fixed_k: int | None = None       # SLED: draft exactly K always
    predictor: "PredictorOperatingPoint | None" = None
    population: DevicePopulation = dataclasses.field(default_factory=DevicePopulation)

    # context / workload
    prompt_len_mean: int = 128
    response_len_mean: int = 196     # geometric; session re-opens when done

    # server
    coeffs: EstimatorCoeffs = dataclasses.field(default_factory=lambda: A100_QWEN32B)
    #: batch-selection policy, any name registered in
    #: repro.core.scheduler (wisp/slo, fcfs, edf, priority)
    scheduler: str = "slo"
    prefix_cache: bool = True        # SLED: False (re-prefill every round)
    #: resident KV pool (tokens).  A100-80GB serving Qwen3-32B: ~16 GB left
    #: after weights at ~0.4 MB/token of KV -> ~48k tokens.  When aggregate
    #: session context exceeds the pool, the prefix cache thrashes: a
    #: request finds its prefix evicted with probability = overflow fraction
    #: and must re-prefill (cold start).  This is what bounds capacity at
    #: loose SLO classes.
    kv_pool_tokens: int = 48_000
    dispatch_interval: float = 0.004 # epoch spacing when GPU idle
    memory_budget_tokens: int = 600_000
    max_batch_requests: int = 64
    guard_time: float = 0.005
    #: truth = estimator * lognormal(sigma) — models profiling error + jitter
    latency_noise_sigma: float = 0.05
    #: occasional compute spike (kernel re-autotune, preemption): Fig. 8's
    #: compute-dominant violation regime
    spike_prob: float = 0.01
    spike_scale: float = 3.0

    # centralized mode (no drafting at all)
    centralized: bool = False

    network: NetworkModel = dataclasses.field(default_factory=NetworkModel)

    def slo_for_device(self, i: int) -> float:
        if self.homogeneous_slo is not None:
            return self.homogeneous_slo
        return self.slo_speeds[i % len(self.slo_speeds)]
