"""System capacity:  Cap(tau) = max N with P[token-speed < tau] <= eps
(paper Eq. 20), found by exponential bracket + bisection over N."""
from __future__ import annotations

from typing import Callable

from repro.sim.config import SimConfig
from repro.sim.engine import simulate


def violation_rate(make_cfg: Callable[[int], SimConfig], n: int) -> float:
    return simulate(make_cfg(n)).violation_rate()


def capacity_search(
    make_cfg: Callable[[int], SimConfig],
    *,
    eps: float = 0.10,
    n_lo: int = 1,
    n_hi_cap: int = 2048,
    verbose: bool = False,
) -> int:
    """Largest N whose steady-state violation rate stays <= eps.

    Violation rate is monotone-ish in N but noisy; bisection on a single
    seed is reproducible (the sim is deterministic given (cfg, N)).
    """
    if violation_rate(make_cfg, n_lo) > eps:
        return 0
    # exponential bracket
    lo, hi = n_lo, n_lo
    while hi < n_hi_cap:
        hi = min(hi * 2, n_hi_cap)
        v = violation_rate(make_cfg, hi)
        if verbose:
            print(f"  bracket N={hi}: violation={v:.3f}")
        if v > eps:
            break
        lo = hi
    else:
        return hi
    if hi >= n_hi_cap and violation_rate(make_cfg, n_hi_cap) <= eps:
        return n_hi_cap
    # bisect (lo feasible, hi infeasible)
    while hi - lo > 1:
        mid = (lo + hi) // 2
        v = violation_rate(make_cfg, mid)
        if verbose:
            print(f"  bisect  N={mid}: violation={v:.3f}")
        if v <= eps:
            lo = mid
        else:
            hi = mid
    return lo
