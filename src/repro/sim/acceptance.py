"""Acceptance + rejection-predictor models for the simulator.

The *true* accept/reject sequence of a draft block is iid Bernoulli(alpha)
per position (alpha set by the draft/target pair — paper Table 5 baseline).
Verification stops at the first true rejection.

The predictor is modeled by its measured operating point (paper Table 4):
at each drafted position it sees the token's truth and errs with

    P(flag reject | truly accepted)  = fnr   (1 - Rec_1: lost coverage)
    P(pass        | truly rejected)  = fpr   (1 - Spec: waste driver)

Drafting under *stop-at-first-predicted-rejection* stops at the first
flagged position (that token is not sent), giving exactly the Theorem-1
waste structure: waste > 0 requires a false pass at the true first
rejection.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class PredictorOperatingPoint:
    """Operating point of a rejection predictor (paper Table 4)."""

    fpr: float     # P(predict accept | truly rejected)
    fnr: float     # P(predict reject | truly accepted)
    latency: float = 0.46e-3   # per-token inference cost (Tab. 11, RPi5 MLP)

    @classmethod
    def mlp(cls):
        return cls(fpr=0.425, fnr=0.199)

    @classmethod
    def tree(cls):                      # XGBoost row of Table 4
        return cls(fpr=0.798, fnr=0.068, latency=0.35e-3)

    @classmethod
    def oracle(cls):
        return cls(fpr=0.0, fnr=0.0, latency=0.0)


@dataclasses.dataclass
class DraftOutcome:
    n_drafted: int        # tokens physically drafted (incl. flagged one)
    n_sent: int           # submitted for verification
    accept_len: int       # L: verifier-accepted prefix of the sent block
    wasted: int           # W = (n_drafted - L)^+  (paper Eq. 7)


class AcceptanceModel:
    def __init__(self, alpha: float, rng: np.random.Generator):
        self.alpha = alpha
        self.rng = rng

    def draft_block(
        self,
        k_max: int,
        predictor: PredictorOperatingPoint | None,
        fixed_k: int | None = None,
    ) -> DraftOutcome:
        """Simulate one speculate-verify iteration's edge side + truth."""
        k_cap = fixed_k if fixed_k is not None else k_max
        truth = self.rng.random(k_cap) < self.alpha      # True = would accept
        # true first rejection (index of first False), len if none
        rej = np.nonzero(~truth)[0]
        first_rej = int(rej[0]) if len(rej) else k_cap

        if predictor is None or fixed_k is not None:
            n_drafted = k_cap
            n_sent = k_cap
            accept_len = first_rej
            return DraftOutcome(
                n_drafted, n_sent, accept_len, max(0, n_drafted - accept_len)
            )

        # stop-at-first-predicted-rejection
        n_drafted = 0
        n_sent = 0
        for i in range(k_cap):
            n_drafted += 1
            if truth[i]:
                flag = self.rng.random() < predictor.fnr
            else:
                flag = self.rng.random() >= predictor.fpr
            if flag:
                break                  # flagged token is NOT sent
            n_sent += 1
        accept_len = min(n_sent, first_rej)
        return DraftOutcome(
            n_drafted, n_sent, accept_len, max(0, n_drafted - accept_len)
        )
