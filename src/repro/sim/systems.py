"""System presets: WISP, SLED, centralized (the paper's three columns)."""
from __future__ import annotations

import dataclasses

from repro.sim.acceptance import PredictorOperatingPoint
from repro.sim.config import SimConfig


def wisp(n_devices: int, **kw) -> SimConfig:
    """Predictor-guided dynamic drafting + SLO-aware batching + prefix cache."""
    kw.setdefault("predictor", PredictorOperatingPoint.mlp())
    return SimConfig(
        n_devices=n_devices,
        scheduler="slo",
        prefix_cache=True,
        **kw,
    )


def sled(n_devices: int, **kw) -> SimConfig:
    """Fixed-window drafting + FCFS verification, no prefix cache [21]."""
    kw.setdefault("fixed_k", kw.pop("k", 8))
    return SimConfig(
        n_devices=n_devices,
        scheduler="fcfs",
        prefix_cache=False,
        predictor=None,
        **kw,
    )


def fcfs_cached(n_devices: int, **kw) -> SimConfig:
    """Ablation: WISP's engine (cache + dynamic drafting) but FCFS batching —
    isolates the scheduler's contribution (paper Table 1/Fig. 7 baseline)."""
    kw.setdefault("predictor", PredictorOperatingPoint.mlp())
    return SimConfig(
        n_devices=n_devices,
        scheduler="fcfs",
        prefix_cache=True,
        **kw,
    )


def centralized(n_devices: int, **kw) -> SimConfig:
    """All generation on the server (continuous batched decode)."""
    return SimConfig(
        n_devices=n_devices,
        centralized=True,
        prefix_cache=True,
        predictor=None,
        **kw,
    )


def variant(cfg: SimConfig, **kw) -> SimConfig:
    return dataclasses.replace(cfg, **kw)
