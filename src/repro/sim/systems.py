"""System presets: WISP, SLED, centralized (the paper's three columns),
plus policy ablations drawn from the scheduling-policy registry
(`repro.core.scheduler`) — the simulator accepts any registered policy
name through ``SimConfig.scheduler`` / ``policy_variant``."""
from __future__ import annotations

import dataclasses

from repro.sim.acceptance import PredictorOperatingPoint
from repro.sim.config import SimConfig


def wisp(n_devices: int, **kw) -> SimConfig:
    """Predictor-guided dynamic drafting + SLO-aware batching + prefix cache."""
    kw.setdefault("predictor", PredictorOperatingPoint.mlp())
    return SimConfig(
        n_devices=n_devices,
        scheduler="slo",
        prefix_cache=True,
        **kw,
    )


def sled(n_devices: int, **kw) -> SimConfig:
    """Fixed-window drafting + FCFS verification, no prefix cache [21]."""
    kw.setdefault("fixed_k", kw.pop("k", 8))
    return SimConfig(
        n_devices=n_devices,
        scheduler="fcfs",
        prefix_cache=False,
        predictor=None,
        **kw,
    )


def fcfs_cached(n_devices: int, **kw) -> SimConfig:
    """Ablation: WISP's engine (cache + dynamic drafting) but FCFS batching —
    isolates the scheduler's contribution (paper Table 1/Fig. 7 baseline)."""
    kw.setdefault("predictor", PredictorOperatingPoint.mlp())
    return SimConfig(
        n_devices=n_devices,
        scheduler="fcfs",
        prefix_cache=True,
        **kw,
    )


def centralized(n_devices: int, **kw) -> SimConfig:
    """All generation on the server (continuous batched decode)."""
    return SimConfig(
        n_devices=n_devices,
        centralized=True,
        prefix_cache=True,
        predictor=None,
        **kw,
    )


def edf(n_devices: int, **kw) -> SimConfig:
    """Ablation: WISP's engine but earliest-deadline-first batching —
    deadline *ordering* without Algorithm 1's estimator-validated
    admission (registry policy ``"edf"``)."""
    kw.setdefault("predictor", PredictorOperatingPoint.mlp())
    return SimConfig(
        n_devices=n_devices,
        scheduler="edf",
        prefix_cache=True,
        **kw,
    )


def priority(n_devices: int, **kw) -> SimConfig:
    """Ablation: WISP's engine but strict SLO-class priority batching
    (registry policy ``"priority"`` — the starvation-prone baseline)."""
    kw.setdefault("predictor", PredictorOperatingPoint.mlp())
    return SimConfig(
        n_devices=n_devices,
        scheduler="priority",
        prefix_cache=True,
        **kw,
    )


def policy_variant(policy: str, n_devices: int, **kw) -> SimConfig:
    """WISP's engine (cache + dynamic drafting) under any registered
    scheduling policy — the generic form of `fcfs_cached`, used by the
    benchmark drivers to sweep ``--policy`` through the simulator."""
    kw.setdefault("predictor", PredictorOperatingPoint.mlp())
    return SimConfig(
        n_devices=n_devices,
        scheduler=policy,
        prefix_cache=True,
        **kw,
    )


def variant(cfg: SimConfig, **kw) -> SimConfig:
    return dataclasses.replace(cfg, **kw)
