"""Trace-driven discrete-event simulator for distributed speculative
serving (paper §5.1: "scalable verification-workload simulator").

Reproduces the paper's end-to-end tables with the *same control code* the
functional server uses (scheduler, estimator, WDT accounting), driven by an
analytic latency model instead of real hardware:

  * Table 1 / Fig. 7 — SLO violation rates (FCFS vs WISP) vs device count
  * Table 2        — system capacity per SLO class (WISP / SLED / central)
  * Table 3        — system goodput
  * Fig. 1         — WDT vs device goodput
  * Fig. 8         — queue-vs-compute violation attribution
"""
from repro.sim.config import A100_QWEN32B, SimConfig, DevicePopulation
from repro.sim.acceptance import AcceptanceModel, PredictorOperatingPoint
from repro.sim.engine import SimResult, simulate
from repro.sim.systems import (
    centralized,
    edf,
    fcfs_cached,
    policy_variant,
    priority,
    sled,
    wisp,
)
from repro.sim.capacity import capacity_search, violation_rate

__all__ = [
    "SimConfig",
    "DevicePopulation",
    "A100_QWEN32B",
    "AcceptanceModel",
    "PredictorOperatingPoint",
    "simulate",
    "SimResult",
    "wisp",
    "sled",
    "centralized",
    "edf",
    "fcfs_cached",
    "policy_variant",
    "priority",
    "capacity_search",
    "violation_rate",
]
