"""Discrete-event serving simulator.

One verifier (GPU/TPU slice) + N edge devices.  Every control decision —
batch selection, deadlines, utility ordering — runs through the *same*
scheduler/estimator code as the functional server (`repro.core.scheduler`);
only execution latency is analytic:

    t_true(batch) = estimator(batch) * LogNormal(0, sigma) [* spike]

Devices loop speculate -> submit -> wait verdict -> commit; sessions close
when the response completes and reopen with a fresh prompt, keeping load
stationary.  Centralized mode replaces drafting with continuous batched
decode on the server.
"""
from __future__ import annotations

import dataclasses
import heapq

import numpy as np

from repro.core.estimator import BatchShape
from repro.core.scheduler import SchedulerConfig, VerifyRequest, make_policy
from repro.sim.acceptance import AcceptanceModel
from repro.sim.config import SimConfig


@dataclasses.dataclass
class IterRecord:
    device: int
    t_arrival: float
    slo_speed: float
    n_drafted: int
    n_sent: int
    n_accepted: int
    n_committed: int
    t_draft: float
    t_network: float
    t_queue: float
    t_verify: float
    context: int
    violated: bool

    @property
    def t_total(self) -> float:
        return self.t_draft + self.t_network + self.t_queue + self.t_verify

    @property
    def speed(self) -> float:
        return self.n_committed / max(self.t_total, 1e-9)

    @property
    def wasted(self) -> int:
        return max(0, self.n_drafted - self.n_accepted)


@dataclasses.dataclass
class ResponseRecord:
    """One completed response: the paper's SLO unit — achieved end-to-end
    token speed over the whole response (per-iteration speed is dominated
    by the variance of L; a 0-accept round is not an SLO violation if the
    stream recovers)."""

    device: int
    slo_speed: float
    n_tokens: int
    t_start: float
    t_end: float

    @property
    def speed(self) -> float:
        return self.n_tokens / max(self.t_end - self.t_start, 1e-9)

    @property
    def violated(self) -> bool:
        return self.speed < self.slo_speed


@dataclasses.dataclass
class SimResult:
    records: list
    sim_time: float
    cfg: SimConfig
    responses: list = dataclasses.field(default_factory=list)

    # -- aggregates (post-warmup) -----------------------------------------
    def _live(self):
        return [r for r in self.records if r.t_arrival >= self.cfg.warmup]

    def _live_responses(self):
        return [r for r in self.responses if r.t_start >= self.cfg.warmup]

    def violation_rate(self, slo_speed: float | None = None) -> float:
        """Fraction of completed responses whose token speed missed the
        class target (falls back to iteration-level when no response
        completed in the horizon)."""
        rs = self._live_responses()
        if slo_speed is not None:
            rs = [r for r in rs if abs(r.slo_speed - slo_speed) < 1e-9]
        if rs:
            return sum(r.violated for r in rs) / len(rs)
        its = self._live()
        if slo_speed is not None:
            its = [r for r in its if abs(r.slo_speed - slo_speed) < 1e-9]
        return sum(r.violated for r in its) / max(len(its), 1)

    def goodput(self) -> float:
        rs = self._live()
        horizon = self.sim_time - self.cfg.warmup
        return sum(r.n_committed for r in rs) / max(horizon, 1e-9)

    def device_goodput(self, device: int) -> float:
        rs = [r for r in self._live() if r.device == device]
        horizon = self.sim_time - self.cfg.warmup
        return sum(r.n_committed for r in rs) / max(horizon, 1e-9)

    def waste_fraction(self) -> float:
        rs = self._live()
        drafted = sum(r.n_drafted for r in rs)
        return sum(r.wasted for r in rs) / max(drafted, 1)

    def acceptance_rate(self) -> float:
        rs = self._live()
        return sum(r.n_accepted for r in rs) / max(sum(r.n_sent for r in rs), 1)

    def mean_speed(self) -> float:
        rs = self._live()
        return float(np.mean([r.speed for r in rs])) if rs else 0.0

    def attribution(self, window: int = 32, rho: float = 1.5):
        """Fig. 8: classify each violated event in the (t_queue, t_verify)
        plane as compute-dominant (t_verify spikes vs the sliding mean,
        paper Eq. 21) or queue-dominant."""
        rs = sorted(self._live(), key=lambda r: r.t_arrival)
        out = []
        hist: list[float] = []
        for r in rs:
            ma = float(np.mean(hist[-window:])) if hist else r.t_verify
            kind = None
            if r.violated:
                kind = "compute" if r.t_verify > rho * max(ma, 1e-9) else "queue"
            out.append(
                {
                    "t_queue": r.t_queue,
                    "t_verify": r.t_verify,
                    "violated": r.violated,
                    "kind": kind,
                }
            )
            hist.append(r.t_verify)
        return out


@dataclasses.dataclass
class _Device:
    idx: int
    slo_speed: float
    draft_speed: float
    acceptance: AcceptanceModel
    context: int = 0            # server-side committed tokens (KV length)
    remaining: int = 0          # response tokens until session end
    alpha_est: float = 0.6      # server's EWMA acceptance estimate
    resp_start: float = 0.0     # wall time the current response began
    resp_tokens: int = 0        # tokens committed to the current response


ARRIVAL, GPU_DONE, RETRY = 0, 1, 2


def simulate(cfg: SimConfig) -> SimResult:
    rng = np.random.default_rng(cfg.seed)
    sched_cfg = SchedulerConfig(
        memory_budget_tokens=cfg.memory_budget_tokens,
        guard_time=cfg.guard_time,
        max_batch_requests=cfg.max_batch_requests,
    )
    # any registered policy name ("wisp"/"slo", "fcfs", "edf", "priority")
    scheduler = make_policy(cfg.scheduler, sched_cfg, cfg.coeffs)

    devices = []
    for i in range(cfg.n_devices):
        speed, alpha = cfg.population.device(i)
        d = _Device(
            idx=i,
            slo_speed=cfg.slo_for_device(i),
            draft_speed=speed,
            acceptance=AcceptanceModel(alpha, np.random.default_rng(cfg.seed * 977 + i)),
        )
        _reset_session(d, cfg, rng)
        devices.append(d)

    if cfg.centralized:
        return _simulate_centralized(cfg, devices, rng)

    records: list[IterRecord] = []
    responses: list[ResponseRecord] = []
    pending: list[VerifyRequest] = []
    seq = [0]   # heap tiebreaker: payloads are not orderable
    payloads: dict[int, dict] = {}
    events: list = []
    gpu_free_at = 0.0
    gpu_busy = False
    rid = 0

    total_ctx = [sum(d.context for d in devices)]   # resident KV tokens
    evict_rng = np.random.default_rng(cfg.seed + 51_977)

    # initial drafting round for every device
    for d in devices:
        _begin_round(d, 0.0, cfg, events, payloads,
                     total_ctx=total_ctx, evict_rng=evict_rng)

    def dispatch(now):
        nonlocal gpu_busy, gpu_free_at
        decision = scheduler.schedule(pending, now)
        if not decision.batch:
            return False
        chosen = {r.req_id for r in decision.batch}
        pending[:] = [r for r in pending if r.req_id not in chosen]
        # true latency: estimator x noise (x occasional spike)
        t_est = scheduler.batch_time(decision.batch)
        noise = float(np.exp(rng.normal(0.0, cfg.latency_noise_sigma)))
        spike = cfg.spike_scale if rng.random() < cfg.spike_prob else 1.0
        t_true = t_est * noise * spike
        gpu_busy = True
        gpu_free_at = now + t_true
        seq[0] += 1
        heapq.heappush(
            events,
            (gpu_free_at, GPU_DONE, seq[0],
             [r.req_id for r in decision.batch], t_true, now),
        )
        return True

    while events:
        ev = heapq.heappop(events)
        now = ev[0]
        if now > cfg.sim_time:
            break
        kind = ev[1]
        if kind == ARRIVAL:
            req = ev[3]
            pending.append(req)
            if not gpu_busy and not dispatch(now):
                seq[0] += 1
                heapq.heappush(
                    events, (now + cfg.dispatch_interval, RETRY, seq[0], None)
                )
        elif kind == RETRY:
            if not gpu_busy and pending and not dispatch(now):
                seq[0] += 1
                heapq.heappush(
                    events, (now + cfg.dispatch_interval, RETRY, seq[0], None)
                )
        else:  # GPU_DONE
            _, _, _, req_ids, t_true, t_started = ev
            gpu_busy = False
            done_ids = set(req_ids)
            for req_id in req_ids:
                info = payloads.pop(req_id)
                d: _Device = info["device"]
                out = info["outcome"]
                committed = out.accept_len + 1
                t_queue = t_started - info["arrival"]
                t_total = info["t_draft"] + info["t_net"] + t_queue + t_true
                rec = IterRecord(
                    device=d.idx,
                    t_arrival=info["arrival"],
                    slo_speed=d.slo_speed,
                    n_drafted=out.n_drafted,
                    n_sent=out.n_sent,
                    n_accepted=out.accept_len,
                    n_committed=committed,
                    t_draft=info["t_draft"],
                    t_network=info["t_net"],
                    t_queue=t_queue,
                    t_verify=t_true,
                    context=d.context,
                    violated=(committed / max(t_total, 1e-9)) < d.slo_speed,
                )
                records.append(rec)
                # server EWMA of acceptance (drives deadline budgets)
                if out.n_sent:
                    d.alpha_est = 0.8 * d.alpha_est + 0.2 * (
                        out.accept_len / out.n_sent
                    )
                total_ctx[0] += committed
                d.context += committed
                d.remaining -= committed
                d.resp_tokens += committed
                if d.remaining <= 0:
                    responses.append(
                        ResponseRecord(
                            device=d.idx,
                            slo_speed=d.slo_speed,
                            n_tokens=d.resp_tokens,
                            t_start=d.resp_start,
                            t_end=now,
                        )
                    )
                    total_ctx[0] -= d.context
                    _reset_session(d, cfg, rng, now=now)
                    total_ctx[0] += d.context
                # next round begins once the verdict reaches the device
                t_next = now + cfg.network.downlink_time()
                _begin_round(d, t_next, cfg, events, payloads,
                             total_ctx=total_ctx, evict_rng=evict_rng)
            if pending and not gpu_busy and not dispatch(now):
                seq[0] += 1
                heapq.heappush(
                    events, (now + cfg.dispatch_interval, RETRY, seq[0], None)
                )

        # rid bookkeeping for closures
        rid += 1

    return SimResult(records=records, sim_time=cfg.sim_time, cfg=cfg,
                     responses=responses)


def _reset_session(d: _Device, cfg: SimConfig, rng, now: float = 0.0):
    d.context = int(rng.geometric(1.0 / cfg.prompt_len_mean))
    d.remaining = int(rng.geometric(1.0 / cfg.response_len_mean))
    d.resp_start = now
    d.resp_tokens = 0


_REQ_ID = [0]


def _begin_round(d: _Device, t0: float, cfg: SimConfig, events, payloads,
                 total_ctx=None, evict_rng=None):
    out = d.acceptance.draft_block(cfg.k_max, cfg.predictor, cfg.fixed_k)
    t_draft = out.n_drafted / d.draft_speed
    if cfg.predictor is not None and cfg.fixed_k is None:
        t_draft += out.n_drafted * cfg.predictor.latency
    t_up = cfg.network.uplink_time(out.n_sent)
    t_net = t_up + cfg.network.downlink_time()
    arrival = t0 + t_draft + t_up
    _REQ_ID[0] += 1
    req_id = _REQ_ID[0]

    if cfg.prefix_cache:
        prefill, cached = 0, d.context
        # KV pool thrashing: beyond the resident pool, this round's prefix
        # was evicted with probability = overflow fraction -> cold start
        if total_ctx is not None and cfg.kv_pool_tokens > 0:
            over = max(0.0, 1.0 - cfg.kv_pool_tokens / max(total_ctx[0], 1))
            if over > 0 and evict_rng.random() < over:
                prefill, cached = d.context, 0
    else:  # SLED: re-prefill the whole committed prefix every round
        prefill, cached = d.context, 0

    expected = d.alpha_est * out.n_sent + 1.0
    budget = max(expected / d.slo_speed - t_draft - t_net, 1e-3)
    req = VerifyRequest(
        req_id=req_id,
        session_id=d.idx,
        slo_class=0,
        arrival=arrival,
        deadline=arrival + budget,
        draft_len=out.n_sent,
        cached_len=cached,
        alpha=d.alpha_est,
        prefill_tokens=prefill,
        enqueued_at=arrival,
    )
    payloads[req_id] = {
        "device": d,
        "outcome": out,
        "arrival": arrival,
        "t_draft": t_draft,
        "t_net": t_net,
    }
    _REQ_ID[0] += 1   # reuse the monotone counter as heap tiebreaker
    heapq.heappush(events, (arrival, ARRIVAL, _REQ_ID[0], req))


def _simulate_centralized(cfg: SimConfig, devices, rng) -> SimResult:
    """Continuous batched autoregressive decode on the server: every step,
    up to max_batch sessions decode one token each (FCFS rotation beyond
    that).  No drafting, no speculative waste."""
    records: list[IterRecord] = []
    responses: list[ResponseRecord] = []
    now = 0.0
    queue = list(range(len(devices)))          # rotation order
    wait_since = {d.idx: 0.0 for d in devices}
    evict_rng = np.random.default_rng(cfg.seed + 51_977)
    while now < cfg.sim_time:
        batch = queue[: cfg.max_batch_requests]
        queue = queue[len(batch):] + batch     # rotate
        total_ctx = sum(d.context for d in devices)
        over = (
            max(0.0, 1.0 - cfg.kv_pool_tokens / max(total_ctx, 1))
            if cfg.kv_pool_tokens > 0 else 0.0
        )
        shapes = [
            (BatchShape(new_tokens=devices[i].context + 1, cached_tokens=0)
             if over > 0 and evict_rng.random() < over
             else BatchShape(new_tokens=1, cached_tokens=devices[i].context))
            for i in batch
        ]
        t_est = cfg.coeffs.predict(shapes)
        noise = float(np.exp(rng.normal(0.0, cfg.latency_noise_sigma)))
        spike = cfg.spike_scale if rng.random() < cfg.spike_prob else 1.0
        t_true = t_est * noise * spike
        for i in batch:
            d = devices[i]
            t_queue = now - wait_since[i]
            t_total = t_queue + t_true + cfg.network.downlink_time()
            records.append(
                IterRecord(
                    device=i,
                    t_arrival=now,
                    slo_speed=d.slo_speed,
                    n_drafted=0,
                    n_sent=0,
                    n_accepted=0,
                    n_committed=1,
                    t_draft=0.0,
                    t_network=cfg.network.downlink_time(),
                    t_queue=t_queue,
                    t_verify=t_true,
                    context=d.context,
                    violated=(1.0 / max(t_total, 1e-9)) < d.slo_speed,
                )
            )
            d.context += 1
            d.remaining -= 1
            d.resp_tokens += 1
            if d.remaining <= 0:
                responses.append(
                    ResponseRecord(
                        device=i,
                        slo_speed=d.slo_speed,
                        n_tokens=d.resp_tokens,
                        t_start=d.resp_start,
                        t_end=now + t_true,
                    )
                )
                _reset_session(d, cfg, rng, now=now + t_true)
            wait_since[i] = now + t_true
        now += t_true
    return SimResult(records=records, sim_time=cfg.sim_time, cfg=cfg,
                     responses=responses)
