"""Fault tolerance walkthrough: the three mechanisms a 1000-node
deployment leans on, exercised end-to-end on CPU.

  1. heartbeat failure detection (verifier replicas + edge devices),
  2. hedged verification dispatch with idempotent commits (stragglers
     and dead replicas),
  3. checkpoint / elastic restore (train state survives restarts and
     mesh-shape changes).

    PYTHONPATH=src python examples/fault_tolerance.py
"""
import tempfile

import numpy as np

from repro.core.estimator import EstimatorCoeffs
from repro.runtime.checkpoint import CheckpointManager
from repro.runtime.failure import HeartbeatMonitor
from repro.runtime.straggler import HedgedDispatcher


def heartbeat_demo():
    print("=== 1. heartbeat failure detection ===")
    mon = HeartbeatMonitor(timeout=2.0,
                           on_death=lambda p, t: print(f"  t={t:4.1f}s  {p} declared DEAD"))
    for r in ("verifier-0", "verifier-1", "verifier-2"):
        mon.register(r, now=0.0)
    # verifier-1 stops beating at t=1
    for t in (1.0, 2.0, 3.0, 4.0):
        for r in ("verifier-0", "verifier-2"):
            mon.beat(r, t)
        if t <= 1.0:
            mon.beat("verifier-1", t)
        mon.sweep(t)
    print(f"  alive: {mon.alive_peers()}")
    mon.on_rejoin = lambda p, t: print(f"  t={t:4.1f}s  {p} REJOINED")
    mon.beat("verifier-1", 5.0)      # node restarts and rejoins
    print(f"  after rejoin: {mon.alive_peers()}\n")


def hedging_demo():
    print("=== 2. hedged dispatch (stragglers + replica failure) ===")
    hd = HedgedDispatcher(["verifier-0", "verifier-1"], guard=0.01,
                          hedge_factor=2.0,
                          on_hedge=lambda k, a, b, t: print(
                              f"  t={t:4.2f}s  batch {k} hedged {a} -> {b}"))
    # dispatch three verification batches with 50 ms ETAs
    for s in range(3):
        hd.dispatch((s, 0), eta=0.05, now=0.0)
    # batch (0,0)'s replica wedges; at t=0.2 the sweep hedges it
    hd.sweep(0.2)
    # both the wedged primary AND the backup eventually answer:
    print(f"  first commit wins: {hd.commit((0, 0))}")
    print(f"  duplicate dropped: {hd.commit((0, 0))}")
    # a replica dies outright: its in-flight work re-dispatches
    plan = hd.remove_replica("verifier-1")
    print(f"  re-dispatch plan after failure: {plan}")
    # the last replica dying parks the work (degraded mode) instead of
    # fake-re-dispatching it back to the dead node...
    plan = hd.remove_replica("verifier-0")
    print(f"  degraded={hd.degraded} orphans={sorted(hd.orphaned)}")
    # ...until a rejoin reclaims the orphans
    plan = hd.add_replica("verifier-0")
    print(f"  reclaimed on rejoin: {plan}")
    print(f"  stats: {hd.stats}\n")


def checkpoint_demo():
    print("=== 3. checkpoint / elastic restore ===")
    from repro.launch.train import train

    with tempfile.TemporaryDirectory() as ck:
        out1 = train("qwen2-7b", reduced=True, steps=6, batch=4, seq=32,
                     ckpt_dir=ck, ckpt_every=3, log_every=0)
        print("  trained 6 steps, checkpoints written")
        # "crash" + restart: resumes from step 6 and continues to 10
        out2 = train("qwen2-7b", reduced=True, steps=10, batch=4, seq=32,
                     ckpt_dir=ck, ckpt_every=5, log_every=0)
        print("  restart resumed automatically and reached step 10")
        # elastic: the same checkpoint restores onto a different mesh shape
        # (restore re-shards host-side; device counts may differ entirely)
        from repro.runtime.checkpoint import restore_checkpoint

        state, meta = restore_checkpoint(ck)
        n = sum(np.asarray(x).size for x in
                __import__("jax").tree.leaves(state["params"]))
        print(f"  elastic restore: step={meta['step']} params={n:,}")


if __name__ == "__main__":
    heartbeat_demo()
    hedging_demo()
    checkpoint_demo()
