"""Train a draft model on the synthetic corpus with the full training
substrate: sharded data pipeline, FSDP/TP shardings, AdamW, remat,
checkpoint/restart.

Default runs a CPU-sized model for a quick demonstration; ``--full`` trains
a ~100M-parameter xLSTM-350M-family config for a few hundred steps (slow on
CPU — the same flags drive the production mesh on real hardware).

    PYTHONPATH=src python examples/train_draft_model.py
    PYTHONPATH=src python examples/train_draft_model.py --full --steps 300
"""
import argparse

from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="~100M-param config, few hundred steps")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--ckpt-dir", default="/tmp/wisp_draft_ckpt")
    args = ap.parse_args()

    if args.full:
        out = train(
            "xlstm-350m",          # smallest assigned arch (~350M at paper
            reduced=False,         # scale; ~100M active in this shape)
            steps=args.steps or 300,
            batch=8,
            seq=512,
            remat=True,
            ckpt_dir=args.ckpt_dir,
            ckpt_every=50,
            log_every=10,
        )
    else:
        out = train(
            "qwen2-7b",
            reduced=True,
            steps=args.steps or 120,
            batch=16,
            seq=128,
            ckpt_dir=args.ckpt_dir,
            ckpt_every=40,
            log_every=10,
        )
    losses = out["losses"]
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"({(1 - losses[-1] / losses[0]) * 100:.1f}% reduction)")
    print(f"checkpoints in {args.ckpt_dir} (restart resumes automatically)")


if __name__ == "__main__":
    main()
