"""End-to-end cluster serving demo (the paper's deployment shape): many
edge devices with heterogeneous SLO classes and draft speeds, one
verification server with SLO-aware batching — driven by the event-driven
cluster runtime, so drafting overlaps in-flight verification and WDT /
queueing / violations are *measured*, not modelled.

Three sections:

  1. **Interference** — the selected ``--policy`` vs the FCFS baseline on
     the same seed against an overloaded single-stream verifier:
     per-class measured goodput, queue times, deadline violations.
     WISP's EDF critical path must beat FCFS on violations (asserted
     when ``--policy wisp``).
  2. **Overlap** — speculative continuation on vs off under
     self-speculation (draft == target, greedy): how much drafting time
     pipelining hides, measured as virtual-horizon speedup + salvage stats.
  3. **Equivalence** — the event-driven runtime commits byte-identical
     per-session token streams to the lock-step driver (asserted).
  4. **Compact payload** — the edge ships `CompactQ` draft statistics
     (O(K·C)) instead of dense (K, V) logit rows (DESIGN.md §9): uplink
     bytes per block, and the compact streams stay byte-identical across
     the event-driven and lock-step drivers (asserted).

With ``--fault-schedule`` the demo instead runs ONLY the chaos section:
the same workload twice — clean vs under the seeded fault schedule with
retries on — and asserts the committed streams are byte-identical
(DESIGN.md §14: faults may only cost time, never change bytes).

    PYTHONPATH=src python examples/serve_cluster.py --devices 8 --rounds 8
    PYTHONPATH=src python examples/serve_cluster.py --devices 8 --policy edf
    PYTHONPATH=src python examples/serve_cluster.py --devices 2 --rounds 2 --sync
    PYTHONPATH=src python examples/serve_cluster.py --devices 2 --rounds 2 \
        --fault-schedule flap
"""
import argparse

import numpy as np

from repro.core.estimator import EstimatorCoeffs
from repro.core.scheduler import SchedulerConfig, available_policies
from repro.core.speculative import CompactQ
from repro.launch.serve import run_serving
from repro.serving.transport import NetworkModel

#: a verifier serving a 32B-class target: per-epoch overhead dominates, so
#: a single-stream (max_batch=1) verifier under many fast edges is the
#: paper's interference regime in miniature
CONTENTION_COEFFS = EstimatorCoeffs(
    a=3.3e-5, b_compute=3.45e-8, b_read=4.6e-6, c=0.030
)
#: interactive token-speed classes (tok/s) matched to the fleet's
#: achievable speeds so scheduling — not feasibility — decides violations
SLO_SPEEDS = {1: 24.0, 2: 16.0, 3: 10.0, 4: 5.0}
DRAFT_SPEEDS = (60.0, 100.0, 160.0)


def _per_class_table(m, horizon):
    print(f"{'class':>6s} {'slo':>6s} {'sessions':>8s} {'viol':>5s} "
          f"{'miss':>5s} {'goodput':>8s} {'queue':>8s}")
    for cls, d in m.per_class().items():
        print(f"{cls:>6d} {d['slo_tok_s']:>6.1f} {d['sessions']:>8d} "
              f"{d['session_violations']:>5d} {d['deadline_violations']:>5d} "
              f"{d['committed'] / max(horizon, 1e-9):>8.1f} "
              f"{d['mean_queue_s'] * 1e3:>7.1f}ms")


def section_interference(args):
    print(f"=== 1. interference: {args.policy} vs fcfs (same seed, "
          "overloaded verifier) ===")
    out = {}
    policies = [args.policy] + (["fcfs"] if args.policy != "fcfs" else [])
    for pol in policies:
        r = run_serving(
            devices=args.devices, rounds=args.rounds, k_max=args.k_max,
            policy=pol, seed=args.seed, verbose=False,
            coeffs=CONTENTION_COEFFS, draft_speeds=DRAFT_SPEEDS,
            slo_speeds=SLO_SPEEDS,
            sched_cfg=SchedulerConfig(max_batch_requests=1),
        )
        m, horizon = r["metrics"], r["result"].horizon
        out[pol] = m
        print(f"\n--- {pol} ---")
        print(f"goodput={m.goodput(horizon):.1f} tok/s  "
              f"measured WDT={m.t_wdt * 1e3:.0f} ms  "
              f"waste={m.waste_fraction():.3f}  "
              f"mean queue={m.mean_queue_time() * 1e3:.1f} ms")
        print(f"deadline violations={m.deadline_violations()}  "
              f"session violations={m.violations()}")
        _per_class_table(m, horizon)
    if args.policy == "wisp":
        w = out["wisp"].deadline_violations()
        f = out["fcfs"].deadline_violations()
        print(f"\nWISP {w} vs FCFS {f} deadline violations")
        assert w <= f, "WISP must not lose to FCFS on deadline violations"
    return out


def section_overlap(args):
    print("\n=== 2. overlap: speculative continuation on vs off "
          "(self-speculation) ===")
    devices = min(args.devices, 4)
    rounds = max(args.rounds, 2)
    res = {}
    for spec in (True, False):
        r = run_serving(
            devices=devices, rounds=rounds, k_max=args.k_max,
            policy=args.policy,
            seed=args.seed, verbose=False, self_draft=True, greedy=True,
            method="greedy", speculate=spec, coeffs=CONTENTION_COEFFS,
            draft_speeds=DRAFT_SPEEDS, slo_speeds=SLO_SPEEDS,
        )
        m, horizon = r["metrics"], r["result"].horizon
        res[spec] = (m, horizon)
        s = m.spec
        print(f"speculate={spec!s:5s}: horizon={horizon * 1e3:7.1f} ms  "
              f"goodput={m.goodput(horizon):7.1f} tok/s  "
              f"commits={s.commits}/{s.guesses}  salvaged={s.salvaged}  "
              f"discarded={s.discarded}")
    h_on, h_off = res[True][1], res[False][1]
    print(f"pipelining speedup: {h_off / max(h_on, 1e-9):.2f}x "
          f"(same committed tokens, drafting hidden under verification)")
    return res


def section_equivalence(args):
    print("\n=== 3. equivalence: event-driven vs lock-step streams ===")
    devices, rounds = min(args.devices, 3), min(args.rounds, 3)
    kw = dict(devices=devices, rounds=rounds, k_max=args.k_max,
              policy=args.policy, seed=args.seed, verbose=False)
    # the event-driven runtime consumes the typed server event stream;
    # the lock-step reference consumes the legacy shim channels — equal
    # streams mean the two APIs report identical outcomes
    ev = run_serving(sync=False, **kw)
    sy = run_serving(sync=True, **kw)
    for i, (de, ds) in enumerate(zip(ev["edges"], sy["edges"])):
        a, b = de.response_tokens, ds.response_tokens
        assert a == b, f"device {i}: stream diverged: {a[:8]} vs {b[:8]}"
        print(f"dev{i}: {len(a)} tokens, byte-identical across drivers")
    print("event-driven == lock-step per-session streams (verified)")


def section_payload(args):
    print("\n=== 4. compact draft payload: O(K·V) -> O(K·C) uplink ===")
    devices, rounds = min(args.devices, 2), min(args.rounds, 2)
    kw = dict(devices=devices, rounds=rounds, k_max=args.k_max,
              policy=args.policy, seed=args.seed, verbose=False,
              q_mode="compact")
    ev = run_serving(sync=False, **kw)
    sy = run_serving(sync=True, **kw)
    for i, (de, ds) in enumerate(zip(ev["edges"], sy["edges"])):
        assert de.response_tokens == ds.response_tokens, \
            f"device {i}: compact stream diverged across drivers"
    net = NetworkModel()
    k, C = args.k_max, 64
    vocab = ev["server"].engine.cfg.vocab
    qc = CompactQ(np.zeros(k, np.float32), np.zeros((k, C), np.int32),
                  np.zeros((k, C), np.float32), np.zeros(k, np.float32))
    print(f"uplink bytes per {k}-token block: "
          f"raw dense (V={vocab}) = {64 + k * 4 + k * vocab * 4}, "
          f"modelled top-{net.q_topk} = {net.uplink_bytes(k)}, "
          f"compact C={C} = {net.uplink_bytes(k, qc)}, "
          f"greedy (ids only) = {net.uplink_bytes(k, None)}")
    print("compact streams byte-identical across drivers (verified)")


def section_chaos(args):
    print(f"\n=== chaos: byte-identity under fault schedule "
          f"{args.fault_schedule!r} ===")
    devices, rounds = min(args.devices, 3), min(args.rounds, 3)
    kw = dict(devices=devices, rounds=rounds, k_max=args.k_max,
              policy=args.policy, seed=args.seed, verbose=False)
    # retry/backoff + idempotent re-submission + verdict dedup must make
    # the faulted run commit the SAME per-session streams as the clean
    # one (DESIGN.md §14): faults may only cost time, never change bytes
    clean = run_serving(**kw)
    chaos = run_serving(fault_schedule=args.fault_schedule,
                        link_timeout=args.link_timeout, **kw)
    for i, (dc, df) in enumerate(zip(clean["edges"], chaos["edges"])):
        a, b = dc.response_tokens, df.response_tokens
        assert a == b, f"device {i}: stream diverged under chaos: " \
                       f"{a[:8]} vs {b[:8]}"
        print(f"dev{i}: {len(a)} tokens, byte-identical under faults")
    c = chaos["metrics"].chaos
    print(f"chaos: retries={c.retries} timeouts={c.timeouts} "
          f"up_drops={c.uplink_drops} down_drops={c.downlink_drops} "
          f"dup_verdicts_dropped={c.dup_verdicts_dropped} "
          f"verdicts_replayed={c.verdicts_replayed} "
          f"link_down={c.link_down_events}")
    assert c.retries > 0 or c.uplink_drops + c.downlink_drops == 0, \
        "messages were lost but the retry loop never fired"
    print("faulted streams byte-identical to fault-free run (verified)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--rounds", type=int, default=8)
    ap.add_argument("--k-max", type=int, default=5)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--policy", default="wisp", choices=available_policies(),
                    help="scheduling policy for sections 1-3 (section 1 "
                         "compares it against the fcfs baseline)")
    ap.add_argument("--sync", action="store_true",
                    help="run only the lock-step reference driver")
    ap.add_argument("--fault-schedule", default=None, metavar="SPEC",
                    help="run ONLY the chaos section: inject this seeded "
                         "fault schedule (preset name or DSL, see "
                         "repro.chaos) and assert the committed streams "
                         "stay byte-identical to a fault-free run")
    ap.add_argument("--link-timeout", type=float, default=0.08,
                    metavar="S", help="per-round retry timeout for the "
                                      "chaos section")
    args = ap.parse_args()

    if args.sync:
        run_serving(devices=args.devices, rounds=args.rounds,
                    k_max=args.k_max, seed=args.seed, sync=True,
                    policy=args.policy)
        return
    if args.fault_schedule:
        section_chaos(args)
        return
    section_interference(args)
    section_overlap(args)
    section_equivalence(args)
    section_payload(args)


if __name__ == "__main__":
    main()
