"""End-to-end serving driver (the paper's deployment shape): many edge
devices with heterogeneous SLO classes and draft speeds, one verification
server with SLO-aware batching, real models on CPU.

Compares the WISP scheduler against FCFS on the same workload and prints
per-class violation behaviour + WDT accounting — Table 1 in miniature.

    PYTHONPATH=src python examples/serve_cluster.py --devices 6 --rounds 10
"""
import argparse

from repro.launch.serve import run_serving


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=6)
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--k-max", type=int, default=6)
    args = ap.parse_args()

    print("=== WISP (SLO-aware batching) ===")
    w = run_serving(
        "qwen2-7b", devices=args.devices, rounds=args.rounds,
        k_max=args.k_max, scheduler="slo", seed=0,
    )
    print("\n=== FCFS baseline (same workload) ===")
    f = run_serving(
        "qwen2-7b", devices=args.devices, rounds=args.rounds,
        k_max=args.k_max, scheduler="fcfs", seed=0,
    )

    wt, ft = w["total"], f["total"]
    print("\n=== comparison ===")
    print(f"{'':>14s} {'WISP':>10s} {'FCFS':>10s}")
    print(f"{'committed':>14s} {wt.committed:>10d} {ft.committed:>10d}")
    print(f"{'violations':>14s} {wt.violations:>10d} {ft.violations:>10d}")
    print(f"{'waste frac':>14s} {wt.waste_fraction:>10.3f} {ft.waste_fraction:>10.3f}")


if __name__ == "__main__":
    main()
