"""Quickstart: one speculative decoding round through WISP's public API.

Builds a reduced draft/target pair on CPU, drafts a block with the
intelligent drafting controller, verifies it losslessly on the server
engine, and prints every quantity the paper defines (K, L, W, WDT).

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.configs import get_config
from repro.core.estimator import analytic_tpu_coeffs
from repro.core.wdt import IterationLog
from repro.models import build
from repro.serving.client import EdgeDevice
from repro.serving.engine import VerificationEngine
from repro.serving.server import WISPServer


def main():
    # 1. models — the paper's Qwen3 pair, reduced to CPU scale
    target_cfg = get_config("qwen2-7b").reduced()
    draft_cfg = target_cfg
    bundle = build(target_cfg)
    target_params = bundle.init(jax.random.PRNGKey(0))
    draft_params = bundle.init(jax.random.PRNGKey(1))

    # 2. verification server: engine + SLO-aware scheduler + estimator.
    #    Attention-family targets get the paged KV backend automatically:
    #    sessions draw 16-token pages (256 on TPU) from a shared pool and
    #    identical prompt prefixes share physical pages.
    engine = VerificationEngine(target_cfg, target_params, max_slots=4,
                                max_len=512, page_size=16)
    # policy picks the batch-selection rule from the scheduling registry:
    # "wisp" (Algorithm 1, the default), "fcfs", "edf" or "priority"
    server = WISPServer(engine, analytic_tpu_coeffs(target_cfg),
                        policy="wisp")
    print(f"engine backend: {'paged' if engine.paged else 'dense'}  "
          f"KV budget: {engine.memory_budget_tokens()} tokens")

    # 3. edge device: draft model + drafting controller
    device = EdgeDevice(draft_cfg, draft_params, k_max=6, draft_speed=50.0)

    # 4. open a session: open_session returns a SessionHandle — state
    #    walks queued -> prefilling -> active -> closed, and first_token
    #    is the response's token 0 once the prompt has prefilled
    #    (immediately, in the default monolithic mode).  The 16-token
    #    "system preamble" fills one full page, so later sessions with
    #    the same preamble share its physical KV page.
    preamble = list(range(100, 116))
    prompt = preamble + [11, 24, 35, 46, 57]
    # queue_on_full=False: this synchronous demo wants a loud failure,
    # not a queued admission, if the KV pool is misconfigured
    handle = server.open_session(0, prompt, slo_class=3, queue_on_full=False)
    first = handle.first_token
    device.start_session(0, prompt, first)
    print(f"prompt={prompt}  handle={handle}")

    # 5. speculate -> verify rounds
    for rnd in range(5):
        res = device.draft_round()
        server.submit(0, res.tokens, res.q_logits, now=rnd * 0.1,
                      t_draft=res.draft_time, t_network=0.012)
        (v,) = server.step(rnd * 0.1)
        device.apply_verdict(v.accept_len, v.token, res.tokens)
        it = IterationLog(
            session_id=0, round_index=rnd,
            n_drafted=res.n_drafted, n_sent=res.n_sent,
            n_accepted=v.accept_len, n_committed=v.emitted,
            t_draft=res.draft_time, t_network=0.012,
            t_queue=v.t_queue, t_verify=v.t_verify,
        )
        print(
            f"round {rnd}: drafted K={it.n_drafted} accepted L={it.n_accepted} "
            f"wasted W={it.wasted} committed +{it.n_committed} "
            f"WDT={it.wdt(1 / 50.0) * 1e3:.1f}ms speed={it.token_speed:.1f} tok/s"
        )

    print("response tokens:", device.response_tokens)
    print("engine stats:", engine.stats)

    # every outcome above also flowed through the server's typed event
    # stream — ADMITTED / FIRST_TOKEN / VERDICT / ... (docs/API.md); this
    # drains it in order
    events = server.pop_events()
    print("server events:", [ev.kind for ev in events])

    # 6. prefix sharing: a second session with the same preamble reuses the
    #    first session's full prompt pages (content-addressed prefix cache)
    server.open_session(1, preamble + [86, 75, 30, 9], slo_class=3,
                        queue_on_full=False)
    st = engine.prefix_cache_stats()
    # st["backend"] tags where the counters come from: the prefix cache is
    # a paged-backend structure; a dense engine reports structural zeros
    print(
        f"second session with same prompt [{st['backend']} backend]: "
        f"prefix hits={st['hits']} pages in use={st['pages_in_use']} "
        f"live KV budget={engine.memory_budget_tokens()} tokens"
    )


if __name__ == "__main__":
    main()
